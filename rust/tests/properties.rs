//! Property-based test suite (substrate::prop): random DAGs × random
//! platforms, checking the paper's invariants end-to-end.
//!
//! Case count: 64 per property by default; override with
//! HETSCHED_PROP_CASES for soak runs.

use hetsched::algos::{run_offline, solve_hlp, solve_qhlp, Offline};
use hetsched::alloc;
use hetsched::graph::{gen, io, paths};
use hetsched::platform::Platform;
use hetsched::runtime::LpBackendKind;
use hetsched::sched::online::{online_schedule, random_topo_order, OnlinePolicy};
use hetsched::sim::validate;
use hetsched::substrate::prop::{check, ensure, ensure_close, ensure_le};
use hetsched::substrate::rng::Rng;

fn random_platform(rng: &mut Rng) -> Platform {
    let k = 1 + rng.below(4);
    let m = k + rng.below(12);
    Platform::hybrid(m, k)
}

fn random_graph(rng: &mut Rng) -> hetsched::graph::TaskGraph {
    let n = 10 + rng.below(40);
    let density = 0.08 + 0.15 * rng.f64();
    gen::hybrid_dag(rng, n, density)
}

#[test]
fn prop_graph_json_roundtrip() {
    check("graph json roundtrip", |rng, _| {
        let g = random_graph(rng);
        let back = io::parse_graph(&io::to_json(&g).to_string()).map_err(|e| e)?;
        ensure(back.succs == g.succs, "arcs preserved")?;
        ensure(back.proc_times == g.proc_times, "times preserved")
    });
}

#[test]
fn prop_topo_order_and_ranks_consistent() {
    check("ranks decrease along arcs", |rng, _| {
        let g = random_graph(rng);
        let alloc: Vec<usize> = (0..g.n_tasks()).map(|_| rng.below(2)).collect();
        let rank = paths::ols_rank(&g, &alloc);
        for j in 0..g.n_tasks() {
            for &s in &g.succs[j] {
                ensure(rank[j] > rank[s], "rank monotone")?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_offline_schedules_feasible_and_certified() {
    check("offline certificates", |rng, case| {
        let g = random_graph(rng);
        let plat = random_platform(rng);
        let hlp = solve_hlp(&g, &plat, LpBackendKind::RustPdhg, 1e-4);
        // LP* sanity: at least the combinatorial lower bound, modulo tol
        let lb = paths::lower_bound(&g, &plat.counts);
        ensure_le(lb * 0.98, hlp.sol.obj, "LP* >= combinatorial LB")?;
        for algo in Offline::ALL {
            let (s, _) =
                run_offline(algo, &g, &plat, Some(&hlp), LpBackendKind::RustPdhg, 1e-4);
            validate(&g, &plat, &s).map_err(|e| format!("case {case} {}: {e}", algo.name()))?;
            // 6-approximation certificate (LP tolerance slack)
            ensure_le(
                s.makespan,
                6.0 * hlp.sol.obj * 1.02 + 1e-9,
                &format!("{} <= 6 LP*", algo.name()),
            )?;
            // any makespan at least the lower bound
            ensure_le(lb * 0.98, s.makespan, "makespan >= LB")?;
        }
        Ok(())
    });
}

#[test]
fn prop_qhlp_certificates_three_types() {
    check("qhlp certificates", |rng, _| {
        let n = 8 + rng.below(25);
        let g = gen::random_dag(rng, n, 0.15, 3);
        let counts = vec![2 + rng.below(6), 1 + rng.below(4), 1 + rng.below(4)];
        let plat = Platform::new(counts);
        let q = 3.0;
        let qhlp = solve_qhlp(&g, &plat, LpBackendKind::RustPdhg, 1e-4);
        for algo in Offline::ALL {
            let (s, _) =
                run_offline(algo, &g, &plat, Some(&qhlp), LpBackendKind::RustPdhg, 1e-4);
            validate(&g, &plat, &s)?;
            ensure_le(
                s.makespan,
                q * (q + 1.0) * qhlp.sol.obj * 1.02,
                &format!("{} <= Q(Q+1) LP*", algo.name()),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_list_scheduling_graham_bound() {
    check("graham bound", |rng, _| {
        let g = random_graph(rng);
        let plat = random_platform(rng);
        let alloc: Vec<usize> = (0..g.n_tasks()).map(|_| rng.below(2)).collect();
        let s = hetsched::sched::list::ols_schedule(&g, &plat, &alloc);
        validate(&g, &plat, &s)?;
        let loads = s.loads(2);
        let cp = paths::critical_path(&g, &|j| g.time_on(j, alloc[j]));
        ensure_le(
            s.makespan,
            loads[0] / plat.m() as f64 + loads[1] / plat.k() as f64 + cp,
            "C_max <= W_cpu/m + W_gpu/k + CP",
        )
    });
}

#[test]
fn prop_online_policies_feasible_and_erls_bounded() {
    check("online policies", |rng, case| {
        let g = random_graph(rng);
        let plat = random_platform(rng);
        let order = random_topo_order(&g, rng);
        let hlp = solve_hlp(&g, &plat, LpBackendKind::RustPdhg, 1e-4);
        for policy in [
            OnlinePolicy::ErLs,
            OnlinePolicy::Eft,
            OnlinePolicy::Greedy,
            OnlinePolicy::Random(case as u64),
            OnlinePolicy::R1,
            OnlinePolicy::R2,
            OnlinePolicy::R3,
        ] {
            let s = online_schedule(&g, &plat, &order, &policy);
            validate(&g, &plat, &s)
                .map_err(|e| format!("{}: {e}", policy.name()))?;
            if matches!(policy, OnlinePolicy::ErLs) {
                let bound = 4.0 * (plat.m() as f64 / plat.k() as f64).sqrt();
                ensure_le(
                    s.makespan,
                    bound * hlp.sol.obj * 1.02,
                    "ER-LS <= 4 sqrt(m/k) LP*",
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_online_deterministic_given_order() {
    check("online determinism", |rng, _| {
        let g = random_graph(rng);
        let plat = random_platform(rng);
        let order = random_topo_order(&g, rng);
        let a = online_schedule(&g, &plat, &order, &OnlinePolicy::ErLs);
        let b = online_schedule(&g, &plat, &order, &OnlinePolicy::ErLs);
        ensure_close(a.makespan, b.makespan, 1e-12, "same makespan")?;
        ensure(a.placements == b.placements, "same placements")
    });
}

#[test]
fn prop_greedy_rules_agree_when_m_equals_k() {
    check("R1=R2=R3 at m=k", |rng, _| {
        let g = random_graph(rng);
        let m = 1 + rng.below(8);
        let plat = Platform::hybrid(m, m);
        let a = alloc::rule_r1(&g, &plat);
        let b = alloc::rule_r2(&g, &plat);
        let c = alloc::rule_r3(&g, &plat);
        ensure(a == b && b == c, "rules coincide when m == k")
    });
}

#[test]
fn prop_hlp_lp_value_below_any_schedule() {
    check("LP* lower-bounds schedules", |rng, _| {
        let g = random_graph(rng);
        let plat = random_platform(rng);
        let hlp = solve_hlp(&g, &plat, LpBackendKind::RustPdhg, 1e-5);
        // an arbitrary feasible schedule (greedy alloc + OLS)
        let alloc = alloc::greedy_min_time(&g);
        let s = hetsched::sched::list::ols_schedule(&g, &plat, &alloc);
        ensure_le(hlp.sol.obj * 0.995, s.makespan, "LP* <= C_max")
    });
}

#[test]
fn prop_simplex_agrees_with_pdhg_on_hlp() {
    // smaller case count: simplex on random HLPs is the slow oracle
    let cfg = hetsched::substrate::prop::PropConfig {
        cases: 12,
        base_seed: 0xCAFE,
    };
    hetsched::substrate::prop::for_all("simplex vs pdhg", &cfg, |rng, _| {
        let n = 6 + rng.below(12);
        let g = gen::hybrid_dag(rng, n, 0.2);
        let plat = random_platform(rng);
        let (lp, _) = hetsched::lp::model::build_hlp(&g, &plat);
        let exact = hetsched::lp::simplex::solve_simplex(&lp).map_err(|e| format!("{e:?}"))?;
        let approx = hetsched::lp::pdhg::solve_rust(
            &lp,
            &hetsched::lp::pdhg::DriveOpts {
                tol: 1e-6,
                ..Default::default()
            },
        );
        ensure_close(exact.obj, approx.obj, 5e-3, "objectives agree")?;
        ensure_le(approx.lower_bound, exact.obj + 1e-6 * (1.0 + exact.obj), "dual bound valid")
    });
}
