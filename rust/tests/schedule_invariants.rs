//! Scheduler-invariant property suite: for every scheduler in the crate
//! — EST, list/OLS, HEFT, every online policy, and the multi-tenant
//! service mode — on ~100 random DAG/platform draws, the produced
//! schedule must satisfy:
//!
//!   (a) no two tasks overlap on one unit,
//!   (b) every task starts after all its predecessors finish,
//!   (c) every task is placed exactly once on a valid unit index
//!       (with its exact allocated duration).
//!
//! All three invariants are checked through the shared
//! `sim::validate_schedule` helper (and its tenant-aware merge
//! `sim::validate_service` for the service mode), the same checkers the
//! service mode uses internally in its own tests.

use hetsched::graph::{gen, Builder};
use hetsched::graph::paths::ols_rank;
use hetsched::platform::Platform;
use hetsched::sched::est::est_schedule;
use hetsched::sched::heft::heft_schedule;
use hetsched::sched::list::list_schedule;
use hetsched::sched::online::{online_schedule, random_topo_order, OnlinePolicy};
use hetsched::sched::service::{run_service, Submission};
use hetsched::sim::{validate_placements_no_overlap, validate_schedule, validate_service};
use hetsched::substrate::rng::Rng;

fn hybrid_platform(rng: &mut Rng) -> Platform {
    Platform::hybrid(1 + rng.below(8), 1 + rng.below(4))
}

fn random_alloc(rng: &mut Rng, n: usize, n_types: usize) -> Vec<usize> {
    (0..n).map(|_| rng.below(n_types)).collect()
}

fn all_online_policies(seed: u64) -> Vec<OnlinePolicy> {
    vec![
        OnlinePolicy::ErLs,
        OnlinePolicy::Eft,
        OnlinePolicy::Greedy,
        OnlinePolicy::Random(seed),
        OnlinePolicy::R1,
        OnlinePolicy::R2,
        OnlinePolicy::R3,
    ]
}

#[test]
fn est_list_heft_invariants_on_random_hybrid_draws() {
    let mut rng = Rng::new(0xE57);
    for draw in 0..100u64 {
        let n = 15 + rng.below(50);
        let density = 0.03 + 0.2 * rng.f64();
        let g = gen::hybrid_dag(&mut rng, n, density);
        let plat = hybrid_platform(&mut rng);
        let alloc = random_alloc(&mut rng, n, 2);

        let s = est_schedule(&g, &plat, &alloc);
        validate_schedule(&g, &plat, &s).unwrap_or_else(|e| panic!("EST draw {draw}: {e}"));
        assert_eq!(s.allocation(), alloc, "EST must respect the allocation");

        let prio = ols_rank(&g, &alloc);
        let s = list_schedule(&g, &plat, &alloc, &prio);
        validate_schedule(&g, &plat, &s).unwrap_or_else(|e| panic!("OLS draw {draw}: {e}"));

        let s = heft_schedule(&g, &plat);
        validate_schedule(&g, &plat, &s).unwrap_or_else(|e| panic!("HEFT draw {draw}: {e}"));
    }
}

#[test]
fn online_policy_invariants_on_random_hybrid_draws() {
    let mut rng = Rng::new(0x0A1);
    for draw in 0..100u64 {
        let n = 15 + rng.below(40);
        let g = gen::hybrid_dag(&mut rng, n, 0.02 + 0.15 * rng.f64());
        let plat = hybrid_platform(&mut rng);
        let order = random_topo_order(&g, &mut rng);
        for policy in all_online_policies(draw) {
            let s = online_schedule(&g, &plat, &order, &policy);
            validate_schedule(&g, &plat, &s)
                .unwrap_or_else(|e| panic!("{} draw {draw}: {e}", policy.name()));
        }
    }
}

#[test]
fn three_type_scheduler_invariants() {
    // the Q-type generalizations: EST / list / HEFT / type-agnostic
    // online policies on 3-type platforms
    let mut rng = Rng::new(0x333);
    for draw in 0..30u64 {
        let n = 15 + rng.below(35);
        let g = gen::random_dag(&mut rng, n, 0.02 + 0.1 * rng.f64(), 3);
        let plat = Platform::new(vec![
            1 + rng.below(6),
            1 + rng.below(3),
            1 + rng.below(3),
        ]);
        let alloc = random_alloc(&mut rng, n, 3);

        let s = est_schedule(&g, &plat, &alloc);
        validate_schedule(&g, &plat, &s).unwrap_or_else(|e| panic!("EST3 draw {draw}: {e}"));
        let prio = ols_rank(&g, &alloc);
        let s = list_schedule(&g, &plat, &alloc, &prio);
        validate_schedule(&g, &plat, &s).unwrap_or_else(|e| panic!("OLS3 draw {draw}: {e}"));
        let s = heft_schedule(&g, &plat);
        validate_schedule(&g, &plat, &s).unwrap_or_else(|e| panic!("HEFT3 draw {draw}: {e}"));
        for policy in [
            OnlinePolicy::Eft,
            OnlinePolicy::Greedy,
            OnlinePolicy::Random(draw),
        ] {
            let s = online_schedule(&g, &plat, &(0..n).collect::<Vec<_>>(), &policy);
            validate_schedule(&g, &plat, &s)
                .unwrap_or_else(|e| panic!("{}3 draw {draw}: {e}", policy.name()));
        }
    }
}

#[test]
fn service_mode_invariants_on_random_multi_tenant_draws() {
    // ~25 service draws × 2–5 tenants each: per-tenant precedence +
    // pool-wide no-overlap through the tenant-aware merge validator
    let mut rng = Rng::new(0x5E2);
    let policies = [
        OnlinePolicy::ErLs,
        OnlinePolicy::Eft,
        OnlinePolicy::Greedy,
        OnlinePolicy::Random(12),
        OnlinePolicy::R2,
    ];
    for draw in 0..25u64 {
        let plat = hybrid_platform(&mut rng);
        let n_tenants = 2 + rng.below(4);
        let subs: Vec<Submission> = (0..n_tenants)
            .map(|t| {
                let n = 10 + rng.below(30);
                let g = gen::hybrid_dag(&mut rng, n, 0.03 + 0.15 * rng.f64());
                let arrival = rng.f64() * 20.0;
                Submission::new(g, arrival, policies[(draw as usize + t) % policies.len()].clone())
            })
            .collect();
        let report = run_service(&plat, &subs);
        validate_service(&plat, &report.tenant_runs(&subs))
            .unwrap_or_else(|e| panic!("service draw {draw}: {e}"));
        // every task decided exactly once, globally
        let total: usize = subs.iter().map(|s| s.graph.n_tasks()).sum();
        assert_eq!(report.decisions.len(), total);
        assert_eq!(report.total_tasks, total);
    }
}

#[test]
fn sharded_service_invariants_on_random_multi_tenant_draws() {
    // the two-level scheduler inherits every global invariant: for
    // random draws at 1–3 shards, the merged schedule passes the same
    // tenant-aware validator as the single loop (per-tenant precedence
    // + pool-wide no-overlap on *global* unit numbering), every task is
    // decided exactly once, and the 1-shard case reproduces
    // run_service decision-for-decision
    use hetsched::sched::service::ShardedService;
    let mut rng = Rng::new(0x54A2);
    let policies = [
        OnlinePolicy::ErLs,
        OnlinePolicy::Eft,
        OnlinePolicy::Greedy,
        OnlinePolicy::Random(3),
    ];
    for draw in 0..15u64 {
        // min type count >= 3 so every shard count in 1..=3 is valid
        let plat = Platform::hybrid(3 + rng.below(6), 3 + rng.below(2));
        let n_tenants = 4 + rng.below(5);
        let subs: Vec<Submission> = (0..n_tenants)
            .map(|t| {
                let n = 8 + rng.below(20);
                let g = gen::hybrid_dag(&mut rng, n, 0.03 + 0.15 * rng.f64());
                // monotone arrivals: sequential admission clamps to the
                // advancing clock, so out-of-order arrivals would
                // legitimately diverge from the batch construct
                let arrival = t as f64 * 0.75;
                Submission::new(g, arrival, policies[(draw as usize + t) % 4].clone())
            })
            .collect();
        let total: usize = subs.iter().map(|s| s.graph.n_tasks()).sum();
        let reference = run_service(&plat, &subs);
        for n_shards in 1..=3usize {
            let mut svc = ShardedService::new(&plat, n_shards).unwrap();
            for sub in &subs {
                svc.admit(sub.clone()).unwrap();
            }
            svc.run();
            let report = svc.report(None);
            validate_service(&plat, &report.tenant_runs(svc.submissions()))
                .unwrap_or_else(|e| panic!("draw {draw}, {n_shards} shards: {e}"));
            assert_eq!(report.decisions.len(), total, "draw {draw}, {n_shards} shards");
            if n_shards == 1 {
                for (a, b) in reference.decisions.iter().zip(&report.decisions) {
                    assert_eq!((a.tenant, a.task), (b.tenant, b.task), "draw {draw}");
                    assert_eq!(a.time.to_bits(), b.time.to_bits(), "draw {draw}");
                }
            }
        }
    }
}

#[test]
fn service_cancellation_invariants_on_random_draws() {
    // ~20 draws: cancel 1–2 tenants mid-stream, drain, then require
    // (a) survivors complete and jointly feasible (merge validator),
    // (b) the cancelled tenants' kept tasks still occupy conflict-free
    //     intervals against everyone, and
    // (c) the shared pool really released the dropped reservations —
    //     total placed tasks + dropped tasks == total submitted.
    use hetsched::sched::service::Service;
    let mut rng = Rng::new(0xCA2C);
    let policies = [OnlinePolicy::Greedy, OnlinePolicy::Eft, OnlinePolicy::ErLs];
    for draw in 0..20u64 {
        let plat = hybrid_platform(&mut rng);
        let n_tenants = 3 + rng.below(3);
        let subs: Vec<Submission> = (0..n_tenants)
            .map(|t| {
                let n = 10 + rng.below(25);
                let g = gen::hybrid_dag(&mut rng, n, 0.03 + 0.15 * rng.f64());
                let arrival = rng.f64() * 10.0;
                Submission::new(g, arrival, policies[(draw as usize + t) % 3].clone())
            })
            .collect();
        let total: usize = subs.iter().map(|s| s.graph.n_tasks()).sum();

        let mut svc = Service::new(&plat, &subs);
        for _ in 0..rng.below(total) {
            let _ = svc.step();
        }
        let victims: Vec<usize> = if draw % 2 == 0 {
            vec![draw as usize % n_tenants]
        } else {
            vec![0, 1 + (draw as usize % (n_tenants - 1))]
        };
        let mut dropped = 0;
        for &v in &victims {
            dropped += svc.cancel(v).dropped_tasks;
        }
        svc.run();
        let report = svc.report(None);

        validate_service(&plat, &report.tenant_runs(&subs))
            .unwrap_or_else(|e| panic!("cancel draw {draw}: {e}"));
        // decision accounting: every processed arrival is either a kept
        // placement or one of the reservations the cancel rewound
        let placed: usize = report.tenants.iter().map(|t| t.n_placed).sum();
        assert_eq!(
            report.decisions.len(),
            placed + dropped,
            "draw {draw}: kept + dropped must cover all processed arrivals"
        );
        assert!(placed <= total);
        for t in &report.tenants {
            assert_eq!(t.cancelled_at.is_some(), victims.contains(&t.tenant));
            if t.cancelled_at.is_none() {
                assert_eq!(t.n_placed, t.n_tasks, "draw {draw}: survivor incomplete");
            }
        }
        // merged no-overlap including cancelled tenants' kept tasks
        validate_placements_no_overlap(
            report.tenants.iter().flat_map(|t| &t.schedule.placements),
        )
        .unwrap_or_else(|e| panic!("draw {draw}: overlap after cancel: {e}"));
        // cascade invariant: a cancelled tenant's kept tasks never depend
        // on dropped ones, and their precedences hold
        for (i, t) in report.tenants.iter().enumerate() {
            if t.cancelled_at.is_none() {
                continue;
            }
            let g = &subs[i].graph;
            let mut placed = vec![None; g.n_tasks()];
            for (&j, p) in t.kept_tasks.iter().zip(&t.schedule.placements) {
                placed[j] = Some(*p);
            }
            for &j in &t.kept_tasks {
                for &pr in &g.preds[j] {
                    let pp = placed[pr].unwrap_or_else(|| {
                        panic!("draw {draw}: kept task {j} depends on dropped {pr}")
                    });
                    assert!(
                        placed[j].unwrap().start >= pp.finish - 1e-9,
                        "draw {draw}: kept precedence {pr}->{j}"
                    );
                }
            }
        }
    }
}

/// Seed matrix for the cross-policy differential fuzz: one deterministic
/// multi-tenant draw per (seed row, tenant-count column).
fn differential_draw(seed: u64, n_tenants: usize) -> (Platform, Vec<Submission>) {
    let mut rng = Rng::new(0xD1FF ^ (seed * 1337 + n_tenants as u64));
    let plat = hybrid_platform(&mut rng);
    let policies = [
        OnlinePolicy::ErLs,
        OnlinePolicy::Eft,
        OnlinePolicy::Greedy,
        OnlinePolicy::Random(seed),
        OnlinePolicy::R2,
    ];
    let subs: Vec<Submission> = (0..n_tenants)
        .map(|t| {
            let n = 10 + rng.below(30);
            let g = gen::hybrid_dag(&mut rng, n, 0.03 + 0.15 * rng.f64());
            let arrival = rng.f64() * 20.0;
            Submission::new(g, arrival, policies[(seed as usize + t) % policies.len()].clone())
        })
        .collect();
    (plat, subs)
}

#[test]
fn service_fifo_bit_identical_to_prepolicy_reference() {
    // cross-policy differential fuzz, leg 1: the policy-aware service
    // under all-FIFO admission must reproduce the retained pre-policy
    // service path (sched::reference::run_service) placement for
    // placement, across the whole seed matrix
    use hetsched::sched::reference;
    for seed in 0..6u64 {
        for n_tenants in [2usize, 4, 6] {
            let (plat, subs) = differential_draw(seed, n_tenants);
            let report = run_service(&plat, &subs);
            let golden = reference::run_service(&plat, &subs);
            for (i, t) in report.tenants.iter().enumerate() {
                assert_eq!(
                    t.schedule.placements, golden[i].placements,
                    "seed {seed}, {n_tenants} tenants, tenant {i}: FIFO drifted \
                     from the pre-policy reference"
                );
            }
        }
    }
}

#[test]
fn service_weighted_stretch_equal_weights_band_equivalent_to_fifo() {
    // cross-policy differential fuzz, leg 2: WeightedStretch with equal
    // weights only ever reorders admissions *inside fully-busy pool
    // windows* — whenever the pool has an idle unit at the head of the
    // stream the order is FIFO by construction.  Across the seed matrix
    // that pins band-equivalence with the FIFO baseline: same tasks at
    // the same virtual times feasibly placed, per-tenant stream order
    // intact, and the fairness metrics within a band of FIFO's (never
    // collapsing, and on net no worse — reordering by current stretch
    // is a max-stretch lever, not a throughput lever).
    use hetsched::sched::service::TenantPolicy;
    let mut ratio_log_sum = 0.0f64;
    let mut n_runs = 0usize;
    for seed in 0..6u64 {
        for n_tenants in [2usize, 4, 6] {
            let (plat, subs) = differential_draw(seed, n_tenants);
            let fifo = run_service(&plat, &subs);
            let ws_subs: Vec<Submission> = subs
                .iter()
                .map(|s| {
                    s.clone()
                        .with_admission(TenantPolicy::WeightedStretch { weight: 1.0 })
                })
                .collect();
            let ws = run_service(&plat, &ws_subs);
            validate_service(&plat, &ws.tenant_runs(&ws_subs))
                .unwrap_or_else(|e| panic!("seed {seed}/{n_tenants}: {e}"));
            assert_eq!(ws.total_tasks, fifo.total_tasks);
            assert_eq!(ws.decisions.len(), fifo.decisions.len());
            // per-draw band: equal-weight reordering must never blow up
            // the stretch tail relative to FIFO
            assert!(
                ws.max_stretch <= fifo.max_stretch * 1.25 + 1e-9,
                "seed {seed}/{n_tenants}: WS max stretch {} vs FIFO {}",
                ws.max_stretch,
                fifo.max_stretch
            );
            ratio_log_sum += (ws.max_stretch / fifo.max_stretch).ln();
            n_runs += 1;
        }
    }
    // on net across the matrix the reordering helps (geometric mean of
    // the max-stretch ratio at or below 1)
    let geo_mean = (ratio_log_sum / n_runs as f64).exp();
    assert!(
        geo_mean <= 1.0 + 1e-9,
        "equal-weight WS should not lose to FIFO on net: geo-mean ratio {geo_mean}"
    );
}

#[test]
fn service_single_tenant_golden_parity_with_online() {
    // acceptance: single-tenant service-mode placements match
    // sched::online exactly, for every policy, across random draws
    let mut rng = Rng::new(0x90D);
    for draw in 0..12u64 {
        let g = gen::hybrid_dag(&mut rng, 20 + rng.below(40), 0.1);
        let plat = hybrid_platform(&mut rng);
        let order = random_topo_order(&g, &mut rng);
        for policy in all_online_policies(draw) {
            let expect = online_schedule(&g, &plat, &order, &policy);
            let subs =
                vec![Submission::new(g.clone(), 0.0, policy).with_order(order.clone())];
            let report = run_service(&plat, &subs);
            assert_eq!(report.tenants[0].schedule.placements, expect.placements);
        }
    }
}

/// 6 fully-connected layers of 6 tasks whose costs span the *admissible*
/// extreme range: near the 2^31 time-unit tick headroom on one type,
/// 1e-300 on the other.  Path sums along every chain exceed the tick
/// clock's range, so finish times saturate at `Tick::MAX` — the
/// monotone "never finishes" ceiling — instead of wrapping.
fn extreme_cost_dag() -> hetsched::graph::TaskGraph {
    let huge = hetsched::sched::engine::MAX_TIME_UNITS - 1.0;
    let mut b = Builder::new("extreme");
    let mut prev: Vec<usize> = Vec::new();
    for layer in 0..6 {
        let mut cur = Vec::new();
        for k in 0..6 {
            let i = layer * 6 + k;
            let times = match i % 3 {
                0 => vec![huge, 1e-300],
                1 => vec![1e-300, huge],
                _ => vec![huge, huge],
            };
            let t = b.add_task(&format!("t{i}"), times);
            for &p in &prev {
                b.add_arc(p, t);
            }
            cur.push(t);
        }
        prev = cur;
    }
    b.build()
}

#[test]
fn beyond_headroom_costs_rejected_at_build() {
    // Re-pin of the old extreme-cost contract: 1e308 costs used to be
    // admitted and silently saturate Tick::quantize; under the new
    // semantics graph construction rejects them outright (Err at
    // try_build, same text from validate), so no scheduler ever sees a
    // cost the tick clock cannot represent.
    let mut b = Builder::new("overflow");
    b.add_task("t", vec![1e308, 1e-300]);
    let err = b.try_build().unwrap_err();
    assert!(err.contains("2^31 time-unit tick headroom"), "{err}");
}

#[test]
fn extreme_finite_costs_never_panic() {
    // Regression pin for the NaN-panic class hetlint rule R1 exists
    // for: `sort_by(partial_cmp().unwrap())` in substrate::stats /
    // substrate::bench and the old NaN-rejecting OrdF64 all panicked
    // the moment an intermediate went non-finite.  Costs here are the
    // most extreme ones graph construction now admits (just under the
    // 2^31 tick headroom): chain sums saturate the integer clock, and
    // every scheduler and the full service path (including the
    // Summary/percentile statistics) must run to completion and place
    // every task exactly once — saturating addition keeps the
    // finished-before order, so no comparator or heap invariant breaks.
    let g = extreme_cost_dag();
    let n = g.n_tasks();
    let plat = Platform::hybrid(3, 2);
    let alloc: Vec<usize> = (0..n).map(|i| i % 2).collect();

    let s = est_schedule(&g, &plat, &alloc);
    assert_eq!(s.placements.len(), n, "EST dropped tasks");
    let prio = ols_rank(&g, &alloc);
    let s = list_schedule(&g, &plat, &alloc, &prio);
    assert_eq!(s.placements.len(), n, "OLS dropped tasks");
    let s = heft_schedule(&g, &plat);
    assert_eq!(s.placements.len(), n, "HEFT dropped tasks");

    let order: Vec<usize> = (0..n).collect();
    for policy in all_online_policies(7) {
        let s = online_schedule(&g, &plat, &order, &policy);
        assert_eq!(s.placements.len(), n, "{} dropped tasks", policy.name());
    }

    // Full service run: flow times pinned at the saturated horizon
    // divided by near-zero ideals give astronomically large (but
    // finite) stretches, which must flow through the percentile/Jain
    // aggregates without panicking.
    let subs = vec![
        Submission::new(g.clone(), 0.0, OnlinePolicy::ErLs),
        Submission::new(g, 1.0, OnlinePolicy::Eft),
    ];
    let report = run_service(&plat, &subs);
    assert_eq!(report.decisions.len(), 2 * n);
    for t in &report.tenants {
        assert_eq!(t.schedule.placements.len(), n, "tenant {} dropped tasks", t.tenant);
        // batch runs record no edge latencies: the core never reads the clock
        assert_eq!(t.decision_latency.n, 0);
    }
}
