//! Scheduler-invariant property suite: for every scheduler in the crate
//! — EST, list/OLS, HEFT, every online policy, and the multi-tenant
//! service mode — on ~100 random DAG/platform draws, the produced
//! schedule must satisfy:
//!
//!   (a) no two tasks overlap on one unit,
//!   (b) every task starts after all its predecessors finish,
//!   (c) every task is placed exactly once on a valid unit index
//!       (with its exact allocated duration).
//!
//! All three invariants are checked through the shared
//! `sim::validate_schedule` helper (and its tenant-aware merge
//! `sim::validate_service` for the service mode), the same checkers the
//! service mode uses internally in its own tests.

use hetsched::graph::gen;
use hetsched::graph::paths::ols_rank;
use hetsched::platform::Platform;
use hetsched::sched::est::est_schedule;
use hetsched::sched::heft::heft_schedule;
use hetsched::sched::list::list_schedule;
use hetsched::sched::online::{online_schedule, random_topo_order, OnlinePolicy};
use hetsched::sched::service::{run_service, Submission};
use hetsched::sim::{validate_schedule, validate_service};
use hetsched::substrate::rng::Rng;

fn hybrid_platform(rng: &mut Rng) -> Platform {
    Platform::hybrid(1 + rng.below(8), 1 + rng.below(4))
}

fn random_alloc(rng: &mut Rng, n: usize, n_types: usize) -> Vec<usize> {
    (0..n).map(|_| rng.below(n_types)).collect()
}

fn all_online_policies(seed: u64) -> Vec<OnlinePolicy> {
    vec![
        OnlinePolicy::ErLs,
        OnlinePolicy::Eft,
        OnlinePolicy::Greedy,
        OnlinePolicy::Random(seed),
        OnlinePolicy::R1,
        OnlinePolicy::R2,
        OnlinePolicy::R3,
    ]
}

#[test]
fn est_list_heft_invariants_on_random_hybrid_draws() {
    let mut rng = Rng::new(0xE57);
    for draw in 0..100u64 {
        let n = 15 + rng.below(50);
        let density = 0.03 + 0.2 * rng.f64();
        let g = gen::hybrid_dag(&mut rng, n, density);
        let plat = hybrid_platform(&mut rng);
        let alloc = random_alloc(&mut rng, n, 2);

        let s = est_schedule(&g, &plat, &alloc);
        validate_schedule(&g, &plat, &s).unwrap_or_else(|e| panic!("EST draw {draw}: {e}"));
        assert_eq!(s.allocation(), alloc, "EST must respect the allocation");

        let prio = ols_rank(&g, &alloc);
        let s = list_schedule(&g, &plat, &alloc, &prio);
        validate_schedule(&g, &plat, &s).unwrap_or_else(|e| panic!("OLS draw {draw}: {e}"));

        let s = heft_schedule(&g, &plat);
        validate_schedule(&g, &plat, &s).unwrap_or_else(|e| panic!("HEFT draw {draw}: {e}"));
    }
}

#[test]
fn online_policy_invariants_on_random_hybrid_draws() {
    let mut rng = Rng::new(0x0A1);
    for draw in 0..100u64 {
        let n = 15 + rng.below(40);
        let g = gen::hybrid_dag(&mut rng, n, 0.02 + 0.15 * rng.f64());
        let plat = hybrid_platform(&mut rng);
        let order = random_topo_order(&g, &mut rng);
        for policy in all_online_policies(draw) {
            let s = online_schedule(&g, &plat, &order, &policy);
            validate_schedule(&g, &plat, &s)
                .unwrap_or_else(|e| panic!("{} draw {draw}: {e}", policy.name()));
        }
    }
}

#[test]
fn three_type_scheduler_invariants() {
    // the Q-type generalizations: EST / list / HEFT / type-agnostic
    // online policies on 3-type platforms
    let mut rng = Rng::new(0x333);
    for draw in 0..30u64 {
        let n = 15 + rng.below(35);
        let g = gen::random_dag(&mut rng, n, 0.02 + 0.1 * rng.f64(), 3);
        let plat = Platform::new(vec![
            1 + rng.below(6),
            1 + rng.below(3),
            1 + rng.below(3),
        ]);
        let alloc = random_alloc(&mut rng, n, 3);

        let s = est_schedule(&g, &plat, &alloc);
        validate_schedule(&g, &plat, &s).unwrap_or_else(|e| panic!("EST3 draw {draw}: {e}"));
        let prio = ols_rank(&g, &alloc);
        let s = list_schedule(&g, &plat, &alloc, &prio);
        validate_schedule(&g, &plat, &s).unwrap_or_else(|e| panic!("OLS3 draw {draw}: {e}"));
        let s = heft_schedule(&g, &plat);
        validate_schedule(&g, &plat, &s).unwrap_or_else(|e| panic!("HEFT3 draw {draw}: {e}"));
        for policy in [
            OnlinePolicy::Eft,
            OnlinePolicy::Greedy,
            OnlinePolicy::Random(draw),
        ] {
            let s = online_schedule(&g, &plat, &(0..n).collect::<Vec<_>>(), &policy);
            validate_schedule(&g, &plat, &s)
                .unwrap_or_else(|e| panic!("{}3 draw {draw}: {e}", policy.name()));
        }
    }
}

#[test]
fn service_mode_invariants_on_random_multi_tenant_draws() {
    // ~25 service draws × 2–5 tenants each: per-tenant precedence +
    // pool-wide no-overlap through the tenant-aware merge validator
    let mut rng = Rng::new(0x5E2);
    let policies = [
        OnlinePolicy::ErLs,
        OnlinePolicy::Eft,
        OnlinePolicy::Greedy,
        OnlinePolicy::Random(12),
        OnlinePolicy::R2,
    ];
    for draw in 0..25u64 {
        let plat = hybrid_platform(&mut rng);
        let n_tenants = 2 + rng.below(4);
        let subs: Vec<Submission> = (0..n_tenants)
            .map(|t| {
                let n = 10 + rng.below(30);
                let g = gen::hybrid_dag(&mut rng, n, 0.03 + 0.15 * rng.f64());
                let arrival = rng.f64() * 20.0;
                Submission::new(g, arrival, policies[(draw as usize + t) % policies.len()].clone())
            })
            .collect();
        let report = run_service(&plat, &subs);
        validate_service(&plat, &report.tenant_runs(&subs))
            .unwrap_or_else(|e| panic!("service draw {draw}: {e}"));
        // every task decided exactly once, globally
        let total: usize = subs.iter().map(|s| s.graph.n_tasks()).sum();
        assert_eq!(report.decisions.len(), total);
        assert_eq!(report.total_tasks, total);
    }
}

#[test]
fn service_single_tenant_golden_parity_with_online() {
    // acceptance: single-tenant service-mode placements match
    // sched::online exactly, for every policy, across random draws
    let mut rng = Rng::new(0x90D);
    for draw in 0..12u64 {
        let g = gen::hybrid_dag(&mut rng, 20 + rng.below(40), 0.1);
        let plat = hybrid_platform(&mut rng);
        let order = random_topo_order(&g, &mut rng);
        for policy in all_online_policies(draw) {
            let expect = online_schedule(&g, &plat, &order, &policy);
            let subs =
                vec![Submission::new(g.clone(), 0.0, policy).with_order(order.clone())];
            let report = run_service(&plat, &subs);
            assert_eq!(report.tenants[0].schedule.placements, expect.placements);
        }
    }
}
