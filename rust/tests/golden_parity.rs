//! Golden-parity suite: the engine-backed schedulers must produce
//! exactly the schedules the seed implementations produced.
//!
//! The pre-engine bodies are retained verbatim in `sched::reference`;
//! this suite sweeps 50+ random `gen::hybrid_dag` instances across
//! random platforms and asserts placement-for-placement equality (hence
//! identical makespans) for EST, OLS and every online policy, plus
//! feasibility through `sim::validate`.
//!
//! This coupling is enforced mechanically: `ci.sh`'s reference-coupling
//! check rejects any diff that touches the engine decision files
//! (`sched/{engine,est,heft,online}.rs`) without also touching this
//! suite or `sched/reference.rs` — an intended behavior change must
//! update the oracle, and a pure refactor must at least state here (in
//! the diff) why parity is preserved.  `tools/hetlint` guards the same
//! invariant from the other side: total float order, no unordered
//! iteration, no wall clock in the decision core.

use hetsched::graph::{gen, paths, TaskGraph};
use hetsched::platform::Platform;
use hetsched::sched::online::{online_schedule, random_topo_order, OnlinePolicy};
use hetsched::sched::{est, heft, list, reference};
use hetsched::sim::validate;
use hetsched::substrate::rng::Rng;

const CASES: usize = 60;

fn random_platform(rng: &mut Rng) -> Platform {
    let k = 1 + rng.below(6);
    let m = 1 + rng.below(16);
    Platform::hybrid(m.max(k), k)
}

fn random_instance(rng: &mut Rng) -> TaskGraph {
    let n = 30 + rng.below(130);
    let density = 0.02 + 0.13 * rng.f64();
    gen::hybrid_dag(rng, n, density)
}

fn speed_alloc(g: &TaskGraph) -> Vec<usize> {
    (0..g.n_tasks())
        .map(|j| usize::from(g.p_gpu(j) < g.p_cpu(j)))
        .collect()
}

#[test]
fn est_engine_matches_seed_est() {
    let mut rng = Rng::new(0xE57_0001);
    for case in 0..CASES {
        let g = random_instance(&mut rng);
        let plat = random_platform(&mut rng);
        let alloc = speed_alloc(&g);
        let engine = est::est_schedule(&g, &plat, &alloc);
        let seed = reference::est_schedule(&g, &plat, &alloc);
        validate(&g, &plat, &engine).unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(
            engine.placements, seed.placements,
            "EST diverged from seed on case {case}"
        );
        assert_eq!(engine.makespan, seed.makespan, "EST makespan case {case}");
    }
}

#[test]
fn ols_engine_matches_seed_ols() {
    let mut rng = Rng::new(0x015_0002);
    for case in 0..CASES {
        let g = random_instance(&mut rng);
        let plat = random_platform(&mut rng);
        let alloc = speed_alloc(&g);
        let engine = list::ols_schedule(&g, &plat, &alloc);
        let seed = reference::ols_schedule(&g, &plat, &alloc);
        validate(&g, &plat, &engine).unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(
            engine.placements, seed.placements,
            "OLS diverged from seed on case {case}"
        );
        assert_eq!(engine.makespan, seed.makespan, "OLS makespan case {case}");
    }
}

#[test]
fn list_engine_matches_seed_under_arbitrary_priorities() {
    let mut rng = Rng::new(0x115_0003);
    for case in 0..CASES {
        let g = random_instance(&mut rng);
        let plat = random_platform(&mut rng);
        let alloc: Vec<usize> = (0..g.n_tasks()).map(|_| rng.below(2)).collect();
        let prio: Vec<f64> = (0..g.n_tasks()).map(|_| rng.f64()).collect();
        let engine = list::list_schedule(&g, &plat, &alloc, &prio);
        let seed = reference::list_schedule(&g, &plat, &alloc, &prio);
        validate(&g, &plat, &engine).unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(engine.placements, seed.placements, "list case {case}");
    }
}

#[test]
fn online_engine_matches_seed_all_policies() {
    let mut rng = Rng::new(0x0A1_0004);
    for case in 0..CASES {
        let g = random_instance(&mut rng);
        let plat = random_platform(&mut rng);
        let order = random_topo_order(&g, &mut rng);
        for policy in [
            OnlinePolicy::ErLs,
            OnlinePolicy::Eft,
            OnlinePolicy::Greedy,
            OnlinePolicy::Random(case as u64),
            OnlinePolicy::R1,
            OnlinePolicy::R2,
            OnlinePolicy::R3,
        ] {
            let engine = online_schedule(&g, &plat, &order, &policy);
            let seed = reference::online_schedule(&g, &plat, &order, &policy);
            validate(&g, &plat, &engine)
                .unwrap_or_else(|e| panic!("case {case} {}: {e}", policy.name()));
            assert_eq!(
                engine.placements,
                seed.placements,
                "{} diverged from seed on case {case}",
                policy.name()
            );
            assert_eq!(engine.makespan, seed.makespan);
        }
    }
}

#[test]
fn heft_gap_index_matches_reference_scan() {
    // the gap-index property suite: random DAG/platform draws, engine
    // HEFT (tail tree + gap lists) vs the reference per-unit timeline
    // scan, placement-for-placement.  Insertion-based backfilling is
    // exactly where an index could drift (gap splits, exact fits, exact
    // tick ties between a gap and a tail), so this sweep is the
    // acceptance bar for the gap index.
    let mut rng = Rng::new(0x6A9_0008);
    for case in 0..CASES {
        let g = random_instance(&mut rng);
        let plat = random_platform(&mut rng);
        let engine = heft::heft_schedule(&g, &plat);
        let seed = reference::heft_schedule(&g, &plat);
        validate(&g, &plat, &engine).unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(
            engine.placements, seed.placements,
            "HEFT diverged from reference on case {case}"
        );
        assert_eq!(engine.makespan, seed.makespan, "HEFT makespan case {case}");
    }
}

#[test]
fn heft_gap_index_parity_on_gap_heavy_and_tie_instances() {
    // adversarial shapes for the gap index specifically: wide fork-join
    // layers (every join opens gaps on the losing units), repeated
    // integer and 0.1-style constants (exact tick ties between gap and
    // tail candidates), and tiny unit counts (gap churn on every unit)
    use hetsched::workloads::forkjoin;
    let mut rng = Rng::new(0x6A9_0009);
    for case in 0..10u64 {
        let g = forkjoin::forkjoin(20 + rng.below(60), 2 + rng.below(3), 1, 77 + case);
        let plat = random_platform(&mut rng);
        let a = heft::heft_schedule(&g, &plat);
        let b = reference::heft_schedule(&g, &plat);
        validate(&g, &plat, &a).unwrap_or_else(|e| panic!("forkjoin case {case}: {e}"));
        assert_eq!(a.placements, b.placements, "forkjoin case {case}");
    }
    let int_costs: [(f64, f64); 4] = [(1.0, 2.0), (2.0, 1.0), (3.0, 2.0), (4.0, 1.0)];
    let frac_costs: [(f64, f64); 4] = [(0.1, 0.3), (0.3, 0.1), (0.2, 0.3), (0.6, 0.2)];
    for (farm, label) in [(int_costs, "int"), (frac_costs, "frac")] {
        for case in 0..10 {
            let n = 40 + rng.below(60);
            let density = 0.04 + 0.1 * rng.f64();
            let mut g = gen::hybrid_dag(&mut rng, n, density);
            for j in 0..n {
                let (pc, pg) = farm[rng.below(farm.len())];
                g.proc_times[j] = vec![pc, pg];
            }
            let plat = Platform::hybrid(1 + rng.below(4), 1 + rng.below(3));
            let a = heft::heft_schedule(&g, &plat);
            let b = reference::heft_schedule(&g, &plat);
            validate(&g, &plat, &a).unwrap_or_else(|e| panic!("{label} {case}: {e}"));
            assert_eq!(a.placements, b.placements, "HEFT {label} tie farm case {case}");
        }
    }
}

#[test]
fn heft_tick_tie_semantics_are_pinned() {
    // the deliberate behavior change of the tick-clock PR: "tie" now
    // means equal quantized ticks.  A 1e-10 EFT difference is ≈ 0.86
    // ticks and rounds the two costs to different ticks — the earlier
    // finish (the CPU) wins, exactly the outcome the interim ±1e-12
    // band produced.  A 1e-13 difference lands on the same tick: exact
    // tie -> GPU (Theorem-1 convention).  Engine and reference agree on
    // the NEW semantics in the same diff.
    use hetsched::graph::Builder;
    let plat = Platform::hybrid(1, 1);
    let mut b = Builder::new("band");
    b.add_task("a", vec![1.0, 1.0 + 1e-10]);
    let g = b.build();
    let e = heft::heft_schedule(&g, &plat);
    let r = reference::heft_schedule(&g, &plat);
    assert_eq!(e.placements, r.placements);
    assert_eq!(e.placements[0].ptype, 0, "beyond tick resolution: CPU finishes first");
    let mut b = Builder::new("band2");
    b.add_task("a", vec![1.0, 1.0 + 1e-13]);
    let g = b.build();
    let e = heft::heft_schedule(&g, &plat);
    assert_eq!(e.placements, reference::heft_schedule(&g, &plat).placements);
    assert_eq!(e.placements[0].ptype, 1, "same tick: still a tie, GPU wins");
}

#[test]
fn parity_holds_on_three_type_platforms() {
    // EST and EFT/Greedy/Random generalize to Q types; check parity
    // there too (the paper's §5 grid shape).
    let mut rng = Rng::new(0x3_0005);
    for case in 0..20 {
        let n = 30 + rng.below(60);
        let g = gen::random_dag(&mut rng, n, 0.1, 3);
        let plat = Platform::new(vec![
            1 + rng.below(8),
            1 + rng.below(4),
            1 + rng.below(4),
        ]);
        let alloc: Vec<usize> = (0..n).map(|_| rng.below(3)).collect();
        let engine = est::est_schedule(&g, &plat, &alloc);
        let seed = reference::est_schedule(&g, &plat, &alloc);
        assert_eq!(engine.placements, seed.placements, "EST q3 case {case}");
        let order: Vec<usize> = (0..n).collect();
        for policy in [
            OnlinePolicy::Eft,
            OnlinePolicy::Greedy,
            OnlinePolicy::Random(case as u64),
        ] {
            let a = online_schedule(&g, &plat, &order, &policy);
            let b = reference::online_schedule(&g, &plat, &order, &policy);
            assert_eq!(a.placements, b.placements, "{} q3 case {case}", policy.name());
        }
    }
}

#[test]
fn parity_on_adversarial_tie_heavy_instances() {
    // The Theorem-2/4 instances are all-equal-times tie farms — exactly
    // where tie-break drift would show up first.
    use hetsched::experiments::thm;
    for m in [5usize, 10, 20] {
        let g = thm::thm2_instance(m);
        let plat = Platform::hybrid(m, m);
        let alloc = thm::thm2_proposition_allocation(m);
        let a = est::est_schedule(&g, &plat, &alloc);
        let b = reference::est_schedule(&g, &plat, &alloc);
        assert_eq!(a.placements, b.placements, "thm2 m={m}");
    }
    for (m, k) in [(16usize, 4usize), (64, 16)] {
        let g = thm::thm4_instance(m, k);
        let plat = Platform::hybrid(m, k);
        let order: Vec<usize> = (0..g.n_tasks()).collect();
        for policy in [OnlinePolicy::ErLs, OnlinePolicy::Eft, OnlinePolicy::Greedy] {
            let a = online_schedule(&g, &plat, &order, &policy);
            let b = reference::online_schedule(&g, &plat, &order, &policy);
            assert_eq!(a.placements, b.placements, "thm4 {} m={m} k={k}", policy.name());
        }
    }
}

#[test]
fn parity_on_repeated_constant_costs() {
    // Instances with repeated cost constants (chameleon-style integer
    // costs, or non-representable constants like 0.1 whose path sums
    // differ by ulps) are where tie semantics bite hardest: under the
    // tick clock both sides quantize to the same 2⁻³³ grid, so the ulp
    // clusters the old ±1e-12 band absorbed collapse to exact tick
    // equality on the engine AND the canonical-time reference.  These
    // tie farms pin EST, OLS and every deterministic online policy on
    // exactly that regime.
    let int_costs: [(f64, f64); 4] = [(1.0, 2.0), (2.0, 1.0), (3.0, 2.0), (4.0, 1.0)];
    let frac_costs: [(f64, f64); 4] = [(0.1, 0.3), (0.3, 0.1), (0.2, 0.3), (0.6, 0.2)];
    let mut rng = Rng::new(0xBA4D_0007);
    for (farm, label) in [(int_costs, "int"), (frac_costs, "frac")] {
        for case in 0..15 {
            let n = 40 + rng.below(60);
            let density = 0.04 + 0.1 * rng.f64();
            let mut g = gen::hybrid_dag(&mut rng, n, density);
            for j in 0..n {
                let (pc, pg) = farm[rng.below(farm.len())];
                g.proc_times[j] = vec![pc, pg];
            }
            let plat = random_platform(&mut rng);
            let alloc = speed_alloc(&g);

            let e = est::est_schedule(&g, &plat, &alloc);
            let s = reference::est_schedule(&g, &plat, &alloc);
            validate(&g, &plat, &e).unwrap_or_else(|err| panic!("{label} {case}: {err}"));
            assert_eq!(e.placements, s.placements, "EST {label} tie farm case {case}");

            let e = list::ols_schedule(&g, &plat, &alloc);
            let s = reference::ols_schedule(&g, &plat, &alloc);
            assert_eq!(e.placements, s.placements, "OLS {label} tie farm case {case}");

            let order = random_topo_order(&g, &mut rng);
            for policy in [
                OnlinePolicy::Eft,
                OnlinePolicy::ErLs,
                OnlinePolicy::Greedy,
                OnlinePolicy::R1,
                OnlinePolicy::R2,
                OnlinePolicy::R3,
            ] {
                let a = online_schedule(&g, &plat, &order, &policy);
                let b = reference::online_schedule(&g, &plat, &order, &policy);
                assert_eq!(
                    a.placements,
                    b.placements,
                    "{} {label} tie farm case {case}",
                    policy.name()
                );
            }
        }
    }
}

#[test]
fn parity_on_chameleon_instances() {
    // real benchmark DAGs (block-size-derived repeated costs) through
    // EST and the online policies — the from_json/chameleon regime the
    // ROADMAP originally flagged for tie-semantics drift
    use hetsched::workloads::{chameleon, costs::CostModel};
    for (nb, bs) in [(5usize, 320usize), (8, 128)] {
        let cm = CostModel::hybrid(bs);
        for app in ["potrf", "getrf", "posv"] {
            let g = chameleon::by_name(app, nb, &cm, 3).unwrap();
            let plat = Platform::hybrid(8, 2);
            let alloc = speed_alloc(&g);
            let a = est::est_schedule(&g, &plat, &alloc);
            let b = reference::est_schedule(&g, &plat, &alloc);
            assert_eq!(a.placements, b.placements, "EST {app} nb={nb} bs={bs}");
            let order: Vec<usize> = (0..g.n_tasks()).collect();
            for policy in [OnlinePolicy::Eft, OnlinePolicy::ErLs, OnlinePolicy::Greedy] {
                let x = online_schedule(&g, &plat, &order, &policy);
                let y = reference::online_schedule(&g, &plat, &order, &policy);
                assert_eq!(x.placements, y.placements, "{} {app}", policy.name());
            }
        }
    }
}

#[test]
fn traced_entry_points_preserve_seed_parity() {
    // The obs layer threaded `*_traced(..., sink)` variants through the
    // engine decision files; the public untraced functions delegate
    // with a NoopSink, and emit sites only ever *read* decision state
    // behind `sink.enabled()` — they never feed the comparators.
    // Parity with the retained seed bodies is therefore preserved by
    // construction; this sweep pins it against the oracle directly,
    // with a recording sink attached (the strictest configuration).
    use hetsched::obs::RecordingSink;
    use hetsched::sched::online::online_schedule_traced;
    let mut rng = Rng::new(0x0B5_000A);
    for case in 0..20 {
        let g = random_instance(&mut rng);
        let plat = random_platform(&mut rng);
        let alloc = speed_alloc(&g);

        let mut sink = RecordingSink::new();
        let a = est::est_schedule_traced(&g, &plat, &alloc, &mut sink);
        let b = reference::est_schedule(&g, &plat, &alloc);
        assert_eq!(a.placements, b.placements, "EST traced case {case}");

        let mut sink = RecordingSink::new();
        let a = heft::heft_schedule_traced(&g, &plat, &mut sink);
        let b = reference::heft_schedule(&g, &plat);
        assert_eq!(a.placements, b.placements, "HEFT traced case {case}");

        let order = random_topo_order(&g, &mut rng);
        for policy in [OnlinePolicy::ErLs, OnlinePolicy::Eft, OnlinePolicy::Greedy] {
            let mut sink = RecordingSink::new();
            let a = online_schedule_traced(&g, &plat, &order, &policy, &mut sink);
            let b = reference::online_schedule(&g, &plat, &order, &policy);
            assert_eq!(
                a.placements,
                b.placements,
                "{} traced case {case}",
                policy.name()
            );
        }
    }
}

#[test]
fn tick_quantization_properties_on_seed_costs() {
    // the quantizer underpinning every parity assertion above:
    // round-trip error bounded by half a tick, monotone, and
    // order-preserving beyond tick resolution — checked on the same
    // cost distributions the golden-parity sweeps draw from.
    use hetsched::sched::engine::{Tick, TICK_SHIFT};
    let half_tick = 0.5 / (1u64 << TICK_SHIFT) as f64;

    let mut rng = Rng::new(0x71C_000B);
    let mut costs: Vec<f64> = Vec::new();
    for _ in 0..8 {
        let g = random_instance(&mut rng);
        for j in 0..g.n_tasks() {
            costs.extend(g.proc_times[j].iter().copied());
        }
    }
    // plus the adversarial constants the tie farms use
    costs.extend([0.1, 0.2, 0.3, 0.6, 1.0, 2.0, 3.0, 4.0, 1.0 + 1e-10, 1.0 + 1e-13]);

    for &t in &costs {
        let q = Tick::quantize(t);
        // round-trip bounded by half a tick (round-to-nearest)
        assert!(
            (q.to_f64() - t).abs() <= half_tick,
            "round-trip drift on {t}: {}",
            q.to_f64()
        );
        // dequantize->requantize is the identity (the f64 API boundary
        // is lossless)
        assert_eq!(Tick::quantize(q.to_f64()), q, "boundary round-trip on {t}");
        // nonzero costs never quantize to zero duration
        if t > 0.0 {
            assert!(Tick::quantize_cost(t) >= Tick(1), "cost {t} collapsed to zero");
        }
    }

    // monotone and order-preserving beyond one tick of separation
    let mut sorted = costs.clone();
    sorted.sort_by(f64::total_cmp);
    for w in sorted.windows(2) {
        let (a, b) = (Tick::quantize(w[0]), Tick::quantize(w[1]));
        assert!(a <= b, "quantize not monotone on {} <= {}", w[0], w[1]);
        if w[1] - w[0] > 2.0 * half_tick {
            assert!(a < b, "separated costs {} < {} merged onto one tick", w[0], w[1]);
        }
    }
}

#[test]
fn parity_holds_on_large_in_headroom_costs() {
    // The overflow fix made tick addition *saturating* and moved the
    // admission boundary to 2^31 time-units per cost.  Saturation must
    // be unobservable below the boundary: for costs scaled ~1000× (per
    // task up to ~3e5 time-units, worst-case path sums ~2e7 — two
    // orders under 2^31), the engine's integer clock and the
    // reference's canonical f64 times must still agree placement for
    // placement — the regression this guards is a saturating Add that
    // clips, rounds, or reorders *non*-saturating arithmetic.
    use hetsched::sched::engine::MAX_TIME_UNITS;
    let mut rng = Rng::new(0xB16_000C);
    for case in 0..10 {
        let n = 30 + rng.below(40);
        let mut g = gen::hybrid_dag(&mut rng, n, 0.08);
        let scale = MAX_TIME_UNITS / 2_097_152.0; // 2^31 / 2^21 = 1024.0
        for j in 0..n {
            for t in g.proc_times[j].iter_mut() {
                *t *= scale * (0.5 + rng.f64());
            }
        }
        let plat = random_platform(&mut rng);
        let alloc = speed_alloc(&g);
        let a = est::est_schedule(&g, &plat, &alloc);
        let b = reference::est_schedule(&g, &plat, &alloc);
        validate(&g, &plat, &a).unwrap_or_else(|e| panic!("large case {case}: {e}"));
        assert_eq!(a.placements, b.placements, "EST large-cost case {case}");
        let a = heft::heft_schedule(&g, &plat);
        let b = reference::heft_schedule(&g, &plat);
        assert_eq!(a.placements, b.placements, "HEFT large-cost case {case}");
        let order = random_topo_order(&g, &mut rng);
        for policy in [OnlinePolicy::ErLs, OnlinePolicy::Eft, OnlinePolicy::Greedy] {
            let x = online_schedule(&g, &plat, &order, &policy);
            let y = reference::online_schedule(&g, &plat, &order, &policy);
            assert_eq!(x.placements, y.placements, "{} large-cost case {case}", policy.name());
        }
    }
}

#[test]
fn engine_ranks_unchanged_by_refactor() {
    // ols_rank feeds both engine and reference OLS; pin that the rank
    // computation itself is untouched by asserting monotonicity along
    // arcs on a random instance (guards against accidental edits).
    let mut rng = Rng::new(0x4_0006);
    let g = random_instance(&mut rng);
    let alloc = speed_alloc(&g);
    let rank = paths::ols_rank(&g, &alloc);
    for j in 0..g.n_tasks() {
        for &s in &g.succs[j] {
            assert!(rank[j] > rank[s]);
        }
    }
}
