//! Crash-recovery property suite for the service daemon WAL
//! (`service_net`): the replay == rerun invariant, pinned mechanically.
//!
//! For ~20 seeded contended multi-tenant draws (mixed policies, small
//! hybrid pool so tenants genuinely fight over units, a mid-stream
//! cancel), drive a reference [`Core`] through the full op sequence and
//! drain its report.  Then sever the WAL **at every record boundary**
//! (including byte 0 and the full file) plus one torn, half-written
//! final record, reopen a `Core` from the severed prefix, re-apply the
//! ops the prefix had not yet logged, and require:
//!
//!   (a) the resumed decision stream is bit-identical (`to_bits` on
//!       times) to the uninterrupted run's, and
//!   (b) the canonical report JSON (`wire::report_to_json`, which
//!       excludes wall-clock fields) is byte-identical,
//!
//! for every cut point.  Corruption that is *not* a torn tail must
//! refuse to start: a flipped byte mid-log and a logged decision that
//! disagrees with the recomputed one are both hard errors.

use std::path::{Path, PathBuf};

use hetsched::graph::gen;
use hetsched::platform::Platform;
use hetsched::sched::online::OnlinePolicy;
use hetsched::sched::service::{DecisionRecord, Submission};
use hetsched::service_net::server::Core;
use hetsched::service_net::{wal, wire};
use hetsched::substrate::rng::Rng;

#[derive(Clone)]
enum Op {
    Submit(Submission),
    Cancel(usize),
}

fn apply(core: &mut Core, op: &Op) {
    match op {
        Op::Submit(sub) => {
            core.submit(sub.clone()).expect("valid submission admitted");
        }
        Op::Cancel(t) => {
            core.cancel(*t).expect("live tenant cancelled");
        }
    }
}

/// Ops already durable in a WAL prefix (each op record is written
/// before it is applied, so this is exactly how many ops to skip when
/// resuming).
fn ops_logged(records: &[wal::WalRecord]) -> usize {
    records
        .iter()
        .filter(|r| {
            matches!(
                r,
                wal::WalRecord::Submit { .. }
                    | wal::WalRecord::Cancel { .. }
                    | wal::WalRecord::Drain
            )
        })
        .count()
}

/// Byte offsets one past each `\n` — the record boundaries, including 0
/// and the full length.
fn boundaries(bytes: &[u8]) -> Vec<usize> {
    let mut out = vec![0];
    for (i, &b) in bytes.iter().enumerate() {
        if b == b'\n' {
            out.push(i + 1);
        }
    }
    out
}

fn contended_draw(seed: u64) -> (Platform, Vec<Op>) {
    let mut rng = Rng::new(0x5747_1000 + seed);
    let plat = Platform::hybrid(3, 1);
    let policies = [
        OnlinePolicy::ErLs,
        OnlinePolicy::Eft,
        OnlinePolicy::Greedy,
        OnlinePolicy::Random(seed),
    ];
    let mut ops = Vec::new();
    for t in 0..5usize {
        let g = gen::hybrid_dag(&mut rng, 12, 0.15);
        // tight arrival gaps: tenant t+1 lands while t is mid-stream
        let sub = Submission::new(g, t as f64 * 1.5, policies[t % 4].clone());
        ops.push(Op::Submit(sub));
        if t == 2 {
            ops.push(Op::Cancel(1));
        }
    }
    (plat, ops)
}

fn run_reference(dir: &Path, plat: &Platform, ops: &[Op]) -> (Vec<DecisionRecord>, String) {
    let path = dir.join("reference.wal");
    let (mut core, summary) = Core::open(&path, plat).expect("fresh wal opens");
    assert_eq!(summary.ops, 0);
    assert!(!summary.torn_tail);
    for op in ops {
        apply(&mut core, op);
    }
    let report = wire::report_to_json(&core.report().expect("drains")).to_string();
    (core.decisions().to_vec(), report)
}

fn resume_and_finish(
    path: &Path,
    plat: &Platform,
    ops: &[Op],
    expect_torn: bool,
) -> (Vec<DecisionRecord>, String) {
    let scan = wal::recover(path).expect("severed prefix recovers");
    let skip = ops_logged(&scan.records);
    let (mut core, summary) = Core::open(path, plat).expect("severed prefix opens");
    assert_eq!(summary.torn_tail, expect_torn, "torn flag at {path:?}");
    for op in ops.iter().skip(skip) {
        apply(&mut core, op);
    }
    let report = wire::report_to_json(&core.report().expect("drains")).to_string();
    (core.decisions().to_vec(), report)
}

fn assert_streams_identical(a: &[DecisionRecord], b: &[DecisionRecord], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: decision counts differ");
    for (x, y) in a.iter().zip(b) {
        assert_eq!((x.tenant, x.task), (y.tenant, y.task), "{ctx}");
        assert_eq!(x.time.to_bits(), y.time.to_bits(), "{ctx}");
    }
}

fn scratch_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("hetsched_wal_recovery").join(name);
    std::fs::create_dir_all(&dir).expect("scratch dir");
    dir
}

#[test]
fn replay_equals_rerun_at_every_record_boundary() {
    for seed in 0..20u64 {
        let dir = scratch_dir(&format!("draw{seed}"));
        let (plat, ops) = contended_draw(seed);
        let (ref_decisions, ref_report) = run_reference(&dir, &plat, &ops);
        let bytes = std::fs::read(dir.join("reference.wal")).expect("read reference wal");
        assert_eq!(*bytes.last().unwrap(), b'\n', "wal ends on a record boundary");

        let cut_path = dir.join("cut.wal");
        for b in boundaries(&bytes) {
            std::fs::write(&cut_path, &bytes[..b]).expect("write severed prefix");
            let (dec, rep) = resume_and_finish(&cut_path, &plat, &ops, false);
            let ctx = format!("seed {seed}, cut at byte {b}/{}", bytes.len());
            assert_streams_identical(&ref_decisions, &dec, &ctx);
            assert_eq!(ref_report, rep, "{ctx}: report JSON differs");
        }

        // one torn, half-written final record: sever mid-line
        let torn_at = bytes.len() - 2;
        std::fs::write(&cut_path, &bytes[..torn_at]).expect("write torn prefix");
        let (dec, rep) = resume_and_finish(&cut_path, &plat, &ops, true);
        let ctx = format!("seed {seed}, torn final record");
        assert_streams_identical(&ref_decisions, &dec, &ctx);
        assert_eq!(ref_report, rep, "{ctx}: report JSON differs");

        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn resumed_wal_is_byte_identical_to_uninterrupted_log() {
    // stronger than state equality: after resume + finish, the WAL
    // *file* converges to the uninterrupted one (same records in the
    // same order), because regenerated decisions are bit-identical
    let dir = scratch_dir("wal_bytes");
    let (plat, ops) = contended_draw(99);
    run_reference(&dir, &plat, &ops);
    let bytes = std::fs::read(dir.join("reference.wal")).expect("read reference wal");

    let cut_path = dir.join("cut.wal");
    for b in boundaries(&bytes) {
        std::fs::write(&cut_path, &bytes[..b]).expect("write severed prefix");
        resume_and_finish(&cut_path, &plat, &ops, false);
        let resumed = std::fs::read(&cut_path).expect("read resumed wal");
        assert_eq!(
            bytes, resumed,
            "wal after resume from byte {b} diverges from uninterrupted log"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mid_log_corruption_refuses_to_start() {
    let dir = scratch_dir("corrupt");
    let (plat, ops) = contended_draw(7);
    run_reference(&dir, &plat, &ops);
    let mut bytes = std::fs::read(dir.join("reference.wal")).expect("read reference wal");
    // flip a byte well inside the log (first record's payload)
    bytes[10] ^= 0x01;
    let bad = dir.join("flipped.wal");
    std::fs::write(&bad, &bytes).expect("write corrupted wal");
    assert!(
        Core::open(&bad, &plat).is_err(),
        "mid-log corruption must refuse to start"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn logged_decision_disagreeing_with_replay_refuses_to_start() {
    let dir = scratch_dir("mismatch");
    let (plat, ops) = contended_draw(8);
    run_reference(&dir, &plat, &ops);
    let scan = wal::recover(&dir.join("reference.wal")).expect("scan");
    let mut records = scan.records;
    let di = records
        .iter()
        .position(|r| matches!(r, wal::WalRecord::Decision { .. }))
        .expect("log has decisions");
    if let wal::WalRecord::Decision { rec, .. } = &mut records[di] {
        rec.task += 1;
    }
    let mut text = String::new();
    for r in &records {
        text.push_str(&wire::encode_frame(&wal::record_to_json(r)));
    }
    let bad = dir.join("tampered.wal");
    std::fs::write(&bad, text).expect("write tampered wal");
    let err = Core::open(&bad, &plat).unwrap_err();
    assert!(err.contains("mismatch"), "unexpected error: {err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn platform_mismatch_refuses_to_start() {
    let dir = scratch_dir("platform");
    let (plat, ops) = contended_draw(9);
    run_reference(&dir, &plat, &ops);
    let other = Platform::hybrid(4, 2);
    let err = Core::open(&dir.join("reference.wal"), &other).unwrap_err();
    assert!(err.contains("platform"), "unexpected error: {err}");
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Sharded core: the same replay == rerun discipline, per shard
// ---------------------------------------------------------------------------

/// A contended draw big enough that a 4-shard split leaves every shard
/// with real work (the hybrid(8, 4) pool gives each shard 2 CPUs and
/// 1 GPU).
fn sharded_draw(seed: u64) -> (Platform, Vec<Op>) {
    let mut rng = Rng::new(0x5747_2000 + seed);
    let plat = Platform::hybrid(8, 4);
    let policies = [
        OnlinePolicy::ErLs,
        OnlinePolicy::Eft,
        OnlinePolicy::Greedy,
        OnlinePolicy::Random(seed),
    ];
    let mut ops = Vec::new();
    for t in 0..12usize {
        let g = gen::hybrid_dag(&mut rng, 8, 0.2);
        let sub = Submission::new(g, t as f64 * 0.75, policies[t % 4].clone());
        ops.push(Op::Submit(sub));
        if t == 5 {
            ops.push(Op::Cancel(2));
        }
    }
    (plat, ops)
}

fn run_reference_sharded(
    dir: &Path,
    plat: &Platform,
    shards: usize,
    ops: &[Op],
) -> (Vec<DecisionRecord>, String) {
    let path = dir.join("reference.wal");
    let (mut core, summary) =
        Core::open_sharded(&path, plat, shards).expect("fresh sharded wal opens");
    assert_eq!(summary.ops, 0);
    for op in ops {
        apply(&mut core, op);
    }
    let report = wire::report_to_json(&core.report().expect("drains")).to_string();
    (core.decisions().to_vec(), report)
}

fn resume_and_finish_sharded(
    path: &Path,
    plat: &Platform,
    shards: usize,
    ops: &[Op],
) -> (Vec<DecisionRecord>, String) {
    let scan = wal::recover(path).expect("severed prefix recovers");
    let skip = ops_logged(&scan.records);
    let (mut core, _) =
        Core::open_sharded(path, plat, shards).expect("severed sharded prefix opens");
    for op in ops.iter().skip(skip) {
        apply(&mut core, op);
    }
    let report = wire::report_to_json(&core.report().expect("drains")).to_string();
    (core.decisions().to_vec(), report)
}

#[test]
fn sharded_replay_equals_rerun_at_every_record_boundary() {
    // the tentpole's crash invariant: a 4-shard daemon severed at any
    // record boundary (or mid-record) resumes to the exact decision
    // stream and report bytes of the uninterrupted run — per-shard
    // streams recomputed and bitwise-verified, migrations included
    for seed in 0..6u64 {
        let dir = scratch_dir(&format!("sharded{seed}"));
        let (plat, ops) = sharded_draw(seed);
        let (ref_decisions, ref_report) = run_reference_sharded(&dir, &plat, 4, &ops);
        let bytes = std::fs::read(dir.join("reference.wal")).expect("read reference wal");
        assert_eq!(*bytes.last().unwrap(), b'\n', "wal ends on a record boundary");

        let cut_path = dir.join("cut.wal");
        for b in boundaries(&bytes) {
            std::fs::write(&cut_path, &bytes[..b]).expect("write severed prefix");
            let (dec, rep) = resume_and_finish_sharded(&cut_path, &plat, 4, &ops);
            let ctx = format!("seed {seed}, 4 shards, cut at byte {b}/{}", bytes.len());
            assert_streams_identical(&ref_decisions, &dec, &ctx);
            assert_eq!(ref_report, rep, "{ctx}: report JSON differs");
        }

        let torn_at = bytes.len() - 2;
        std::fs::write(&cut_path, &bytes[..torn_at]).expect("write torn prefix");
        let (dec, rep) = resume_and_finish_sharded(&cut_path, &plat, 4, &ops);
        let ctx = format!("seed {seed}, 4 shards, torn final record");
        assert_streams_identical(&ref_decisions, &dec, &ctx);
        assert_eq!(ref_report, rep, "{ctx}: report JSON differs");

        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn shard_count_mismatch_refuses_to_start() {
    // shard layout is part of the decision stream's identity: a log
    // written at 4 shards must not silently re-slice at 1 (or 2)
    let dir = scratch_dir("shard_mismatch");
    let (plat, ops) = sharded_draw(40);
    run_reference_sharded(&dir, &plat, 4, &ops);
    let path = dir.join("reference.wal");
    for wrong in [1usize, 2] {
        let err = Core::open_sharded(&path, &plat, wrong).unwrap_err();
        assert!(err.contains("shard"), "unexpected error: {err}");
    }
    // and the right count still opens
    Core::open_sharded(&path, &plat, 4).expect("matching shard count reopens");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn single_shard_wal_is_byte_identical_to_preshard_core() {
    // Core::open (the 1-shard wrapper) and an explicit open_sharded(1)
    // write byte-identical logs for the same op stream
    let dir = scratch_dir("one_shard_bytes");
    let (plat, ops) = contended_draw(31);
    run_reference(&dir, &plat, &ops);
    let a = std::fs::read(dir.join("reference.wal")).expect("read wrapper wal");
    let dir2 = scratch_dir("one_shard_bytes_explicit");
    run_reference_sharded(&dir2, &plat, 1, &ops);
    let b = std::fs::read(dir2.join("reference.wal")).expect("read explicit wal");
    assert_eq!(a, b, "1-shard WAL bytes diverge between open() and open_sharded(1)");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&dir2).ok();
}
