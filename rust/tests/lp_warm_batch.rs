//! Warm-start / batch / chain-contraction correctness suite — the
//! acceptance pins of the batched warm-start LP subsystem:
//!
//! * warm-started and cold PDHG solves reach the same LP* within the
//!   solver tolerance, across ≥ 50 random (instance, m, k) grid-neighbor
//!   pairs (primal+dual seeding, shrunken escalating budget and all);
//! * chain-contracted models have the same objective as uncontracted
//!   ones (exact via simplex on small instances, within tolerance via
//!   PDHG on campaign-shaped ones);
//! * the batched driver agrees with the per-item solve path, so LP*
//!   cache entries stay interchangeable;
//! * the blocked (fused) `RustChunk` kernel agrees with the retained
//!   `ScalarChunk` oracle — chunk-for-chunk to rounding and
//!   solve-for-solve within certificate tolerance — over random LPs,
//!   campaign-shaped HLPs, ragged/degenerate shapes (row and variable
//!   counts not multiples of either block width, empty rows/columns,
//!   single-column LPs) and extreme coefficient magnitudes (1e308 and
//!   subnormal entries).

use hetsched::algos::{solve_alloc_grid, solve_hlp_capped};
use hetsched::graph::{gen, TaskGraph};
use hetsched::lp::batch::{solve_batch, BatchJob};
use hetsched::lp::chain::{contract, plan_chains};
use hetsched::lp::model::{build_hlp, build_qhlp, hlp_warm_start, tighten_hlp_box};
use hetsched::lp::pdhg::{
    solve_rust, solve_rust_scalar, BlockedCsr, ChunkBackend, Csr, DriveOpts, RustChunk,
    ScalarChunk, BLOCK, BLOCK_WIDE,
};
use hetsched::lp::simplex::solve_simplex;
use hetsched::lp::SparseLp;
use hetsched::platform::Platform;
use hetsched::substrate::rng::Rng;
use hetsched::workloads::forkjoin;

const TOL: f64 = 1e-3;

fn rel_close(a: f64, b: f64, factor: f64) -> bool {
    (a - b).abs() <= factor * TOL * (1.0 + a.abs().max(b.abs()))
}

/// A random (m, k) and a neighboring config one or two grid steps away.
fn neighbor_configs(rng: &mut Rng) -> (Platform, Platform) {
    let m = 4usize << rng.below(4); // 4..32
    let k = 2usize << rng.below(3); // 2..8
    let (m2, k2) = match rng.below(4) {
        0 => (m * 2, k),
        1 => (m, k * 2),
        2 => (m * 2, k * 2),
        _ => ((m / 2).max(1), k),
    };
    (Platform::hybrid(m, k), Platform::hybrid(m2, k2))
}

#[test]
fn warm_started_grid_solves_match_cold_lp_star() {
    // ≥ 50 (instance, m, k) grid-neighbor pairs: the seeded + contracted
    // + budget-scheduled solve of the neighbor must land on the cold
    // per-item LP* within the PDHG tolerance
    let mut rng = Rng::new(0x3A21);
    let mut pairs = 0;
    for case in 0..50 {
        let n = 10 + rng.below(20);
        let g = gen::hybrid_dag(&mut rng, n, 0.08 + 0.15 * rng.f64());
        let (p1, p2) = neighbor_configs(&mut rng);

        // batched: p2 seeded from p1 (same graph pointer back-to-back)
        let items: Vec<(&TaskGraph, &Platform)> = vec![(&g, &p1), (&g, &p2)];
        let grid = solve_alloc_grid(&items, TOL, 200_000, 2);

        // cold per-item solves of the same two LPs
        let cold1 = solve_hlp_capped(&g, &p1, hetsched::runtime::LpBackendKind::RustPdhg, TOL, 200_000);
        let cold2 = solve_hlp_capped(&g, &p2, hetsched::runtime::LpBackendKind::RustPdhg, TOL, 200_000);

        assert!(
            rel_close(grid[0].sol.obj, cold1.sol.obj, 3.0),
            "case {case} head: {} vs {}",
            grid[0].sol.obj,
            cold1.sol.obj
        );
        assert!(
            rel_close(grid[1].sol.obj, cold2.sol.obj, 3.0),
            "case {case} warm neighbor: {} vs {}",
            grid[1].sol.obj,
            cold2.sol.obj
        );
        pairs += 1;
    }
    assert!(pairs >= 50);
}

#[test]
fn warm_solution_certifies_same_tolerance_as_cold() {
    // the warm-started neighbor's certificate (duality gap) must be as
    // tight as the tolerance demands — warm starting may not loosen it
    let mut rng = Rng::new(0x3A22);
    for _ in 0..8 {
        let g = gen::hybrid_dag(&mut rng, 18, 0.12);
        let (p1, p2) = neighbor_configs(&mut rng);
        let items: Vec<(&TaskGraph, &Platform)> = vec![(&g, &p1), (&g, &p2)];
        let grid = solve_alloc_grid(&items, TOL, 400_000, 2);
        for s in &grid {
            assert!(
                s.sol.gap <= TOL * 1.01,
                "uncertified solve: gap {} > tol {TOL}",
                s.sol.gap
            );
        }
    }
}

#[test]
fn chain_contracted_models_match_uncontracted_exactly() {
    // simplex oracle: contraction preserves the optimum exactly, for
    // HLP and QHLP, on random DAGs and on the chain-heavy fork-join app
    let mut rng = Rng::new(0x3A23);
    for _ in 0..12 {
        let g = gen::hybrid_dag(&mut rng, 12, 0.15);
        let plat = Platform::hybrid(3, 2);
        let plan = plan_chains(&g);
        let (full, _) = build_hlp(&g, &plat);
        let slim = contract(&full, &plan);
        let a = solve_simplex(&full).unwrap().obj;
        let b = solve_simplex(&slim).unwrap().obj;
        assert!((a - b).abs() < 1e-7 * (1.0 + a.abs()), "HLP {a} vs {b}");
    }
    // fork-join: every branch task is interior, so contraction halves
    // the arc rows — the regime the campaign win comes from
    let fj = forkjoin::forkjoin(6, 2, 1, 7);
    let plan = plan_chains(&fj);
    assert!(!plan.is_empty(), "fork-join must contain chains");
    let plat = Platform::hybrid(2, 2);
    let (full, _) = build_hlp(&fj, &plat);
    let slim = contract(&full, &plan);
    assert!(slim.m < full.m);
    let a = solve_simplex(&full).unwrap().obj;
    let b = solve_simplex(&slim).unwrap().obj;
    assert!((a - b).abs() < 1e-7 * (1.0 + a.abs()), "forkjoin {a} vs {b}");
    // QHLP variant
    let g3 = gen::random_dag(&mut rng, 10, 0.2, 3);
    let plan = plan_chains(&g3);
    let plat3 = Platform::new(vec![2, 2, 1]);
    let (full, _) = build_qhlp(&g3, &plat3);
    let slim = contract(&full, &plan);
    let a = solve_simplex(&full).unwrap().obj;
    let b = solve_simplex(&slim).unwrap().obj;
    assert!((a - b).abs() < 1e-7 * (1.0 + a.abs()), "QHLP {a} vs {b}");
}

#[test]
fn contracted_pdhg_matches_full_pdhg_on_campaign_shapes() {
    // PDHG on contracted vs uncontracted models of a campaign-sized
    // instance: same LP* within tolerance, with the same warm start
    let fj = forkjoin::forkjoin(40, 2, 1, 2026);
    let plat = Platform::hybrid(8, 2);
    let (mut full, vars) = build_hlp(&fj, &plat);
    let warm = hlp_warm_start(
        &fj,
        &plat,
        &hetsched::alloc::greedy_min_time(&fj),
        &vars,
    );
    tighten_hlp_box(&mut full, &vars, warm[vars.lambda]);
    let slim = contract(&full, &plan_chains(&fj));
    assert!(slim.m < full.m, "contraction must drop rows here");
    let opts = DriveOpts {
        tol: TOL,
        warm_start: Some(warm),
        ..Default::default()
    };
    let a = solve_rust(&full, &opts);
    let b = solve_rust(&slim, &opts);
    assert!(
        rel_close(a.obj, b.obj, 3.0),
        "full {} vs contracted {}",
        a.obj,
        b.obj
    );
}

#[test]
fn batch_driver_interchangeable_with_sequential_drives() {
    // independent batch jobs reproduce sequential solves bit-for-bit
    // (the cache-interchangeability contract at the driver level)
    let mut rng = Rng::new(0x3A24);
    let mut lps = Vec::new();
    for _ in 0..6 {
        let g = gen::hybrid_dag(&mut rng, 15, 0.1);
        let plat = Platform::hybrid(1 + rng.below(8), 1 + rng.below(4));
        let (lp, _) = build_hlp(&g, &plat);
        lps.push(lp);
    }
    let jobs: Vec<BatchJob> = lps
        .iter()
        .map(|lp| BatchJob::cold(lp.clone(), DriveOpts { tol: TOL, ..Default::default() }))
        .collect();
    let batched = solve_batch(jobs, 3);
    for (lp, sol) in lps.iter().zip(&batched) {
        let alone = solve_rust(lp, &DriveOpts { tol: TOL, ..Default::default() });
        assert_eq!(sol.obj, alone.obj);
        assert_eq!(sol.iters, alone.iters);
        assert_eq!(sol.z, alone.z);
    }
}

/// A random box LP with feasible interior (b drawn above A·midpoint is
/// not required — PDHG handles infeasible-at-start fine; bounds keep
/// everything finite).
fn random_box_lp(rng: &mut Rng) -> SparseLp {
    let n = 3 + rng.below(12);
    let m = 2 + rng.below(10);
    let mut lp = SparseLp {
        n,
        m,
        b: (0..m).map(|_| rng.uniform(0.5, 4.0)).collect(),
        c: (0..n).map(|_| rng.uniform(-2.0, 2.0)).collect(),
        lo: vec![0.0; n],
        hi: (0..n).map(|_| rng.uniform(0.5, 3.0)).collect(),
        ..Default::default()
    };
    for r in 0..m {
        for c in 0..n {
            if rng.chance(0.4) {
                lp.push(r, c, rng.uniform(-1.5, 1.5));
            }
        }
    }
    lp
}

#[test]
fn blocked_kernel_matches_scalar_oracle_on_random_lps() {
    // the blocked (fused matvec+prox) RustChunk vs the retained scalar
    // kernel: chunk-for-chunk agreement to rounding on random LPs, and
    // full-solve agreement within certificate tolerance.  Per-row sums
    // are column-reordered by the blocked layout, so equality is ε, not
    // bitwise — the ε here is far below the 1e-3/1e-4 campaign
    // tolerances the kernels certify.
    let mut rng = Rng::new(0x3A25);
    for case in 0..25 {
        let lp = random_box_lp(&mut rng);
        let mut blocked = RustChunk::new(&lp, 40);
        let mut scalar = ScalarChunk::new(&lp, 40);
        let (mut zb, mut yb) = (vec![0.0; lp.n], vec![0.0; lp.m]);
        let (mut zs, mut ys) = (vec![0.0; lp.n], vec![0.0; lp.m]);
        for chunk in 0..4 {
            let rb = blocked.run_chunk(&mut zb, &mut yb, 1e-2, 1e-2);
            let rs = scalar.run_chunk(&mut zs, &mut ys, 1e-2, 1e-2);
            for (a, b) in zb.iter().zip(&zs) {
                assert!((a - b).abs() < 1e-9, "case {case} chunk {chunk}: z {a} vs {b}");
            }
            for (a, b) in yb.iter().zip(&ys) {
                assert!((a - b).abs() < 1e-9, "case {case} chunk {chunk}: y {a} vs {b}");
            }
            assert!(
                (rb.last.score() - rs.last.score()).abs()
                    < 1e-9 * (1.0 + rs.last.score().abs()),
                "case {case} chunk {chunk}: diag scores diverged"
            );
        }
    }
}

#[test]
fn simd_kernel_matches_oracle_on_ragged_and_degenerate_shapes() {
    // shapes chosen to stress the lane kernels' edges: row/variable
    // counts that are multiples of neither block width (so both the
    // 4-lane body and the ragged tail run), guaranteed-empty last row
    // and last column, and single-column/single-row LPs
    let mut rng = Rng::new(0x3A27);
    for (n, m) in [
        (1usize, 1usize),
        (1, 5),
        (3, 1),
        (7, 5),
        (9, 13),
        (5, 8),
        (8, 5),
        (17, 11),
    ] {
        let mut lp = SparseLp {
            n,
            m,
            b: (0..m).map(|_| rng.uniform(0.5, 2.0)).collect(),
            c: (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect(),
            lo: vec![0.0; n],
            hi: (0..n).map(|_| rng.uniform(0.5, 2.0)).collect(),
            ..Default::default()
        };
        for r in 0..m {
            for c in 0..n {
                // the last row and last column stay structurally empty
                if r + 1 != m && c + 1 != n && rng.chance(0.6) {
                    lp.push(r, c, rng.uniform(-1.5, 1.5));
                }
            }
        }
        let mut blocked = RustChunk::new(&lp, 30);
        let mut scalar = ScalarChunk::new(&lp, 30);
        let (mut zb, mut yb) = (vec![0.0; lp.n], vec![0.0; lp.m]);
        let (mut zs, mut ys) = (vec![0.0; lp.n], vec![0.0; lp.m]);
        for chunk in 0..3 {
            blocked.run_chunk(&mut zb, &mut yb, 1e-2, 1e-2);
            scalar.run_chunk(&mut zs, &mut ys, 1e-2, 1e-2);
            for (a, b) in zb.iter().zip(&zs).chain(yb.iter().zip(&ys)) {
                assert!((a - b).abs() < 1e-9, "({n},{m}) chunk {chunk}: {a} vs {b}");
            }
        }
    }
}

#[test]
fn simd_kernel_survives_extreme_magnitudes() {
    // one huge (1e308) and one subnormal (1e-310) coefficient, each in
    // its own row so per-row reordering cannot cancel catastrophically.
    // Every row's entries end up in the same summation order in the
    // scalar CSR and both blocked widths, so agreement here is exact
    // bit-for-bit even when the dual iterate saturates to +inf — and
    // the primal iterate must stay inside its (finite) box throughout.
    let mut lp = SparseLp {
        n: 3,
        m: 3,
        b: vec![1.0, 1.0, 1.0],
        c: vec![-1.0, 0.5, -0.25],
        lo: vec![0.0; 3],
        hi: vec![1.0; 3],
        ..Default::default()
    };
    lp.push(0, 0, 1e308);
    lp.push(1, 1, 1e-310);
    lp.push(2, 0, 0.75);
    lp.push(2, 2, -1.25);
    let mut blocked = RustChunk::new(&lp, 20);
    let mut scalar = ScalarChunk::new(&lp, 20);
    let (mut zb, mut yb) = (vec![0.0; 3], vec![0.0; 3]);
    let (mut zs, mut ys) = (vec![0.0; 3], vec![0.0; 3]);
    for chunk in 0..3 {
        blocked.run_chunk(&mut zb, &mut yb, 1e-2, 1e-2);
        scalar.run_chunk(&mut zs, &mut ys, 1e-2, 1e-2);
        for (a, b) in zb.iter().zip(&zs).chain(yb.iter().zip(&ys)) {
            assert_eq!(a.to_bits(), b.to_bits(), "chunk {chunk}: {a} vs {b}");
        }
        for (z, (&l, &h)) in zb.iter().zip(lp.lo.iter().zip(&lp.hi)) {
            assert!(*z >= l && *z <= h, "primal left its box: {z}");
        }
    }
    // both block widths agree bitwise on the raw matvec too
    let a = Csr::from_coo(3, 3, &lp.rows, &lp.cols, &lp.vals);
    let b4 = BlockedCsr::from_csr_with_block(&a, BLOCK);
    let b8 = BlockedCsr::from_csr_with_block(&a, BLOCK_WIDE);
    let x = vec![0.5, -0.25, 1.0];
    let (mut o4, mut o8) = (vec![0.0; 3], vec![0.0; 3]);
    b4.matvec(&x, &mut o4);
    b8.matvec(&x, &mut o8);
    for (p, q) in o4.iter().zip(&o8) {
        assert_eq!(p.to_bits(), q.to_bits(), "{p} vs {q}");
    }
}

#[test]
fn blocked_solve_matches_scalar_solve_on_campaign_shapes() {
    // end-to-end drives through both kernels on HLP models (the shapes
    // the campaign actually solves): LP* within certificate tolerance
    let mut rng = Rng::new(0x3A26);
    for _ in 0..6 {
        let g = gen::hybrid_dag(&mut rng, 12 + rng.below(25), 0.1);
        let plat = Platform::hybrid(2 + rng.below(8), 1 + rng.below(4));
        let (lp, _) = build_hlp(&g, &plat);
        let opts = DriveOpts { tol: TOL, ..Default::default() };
        let b = solve_rust(&lp, &opts);
        let s = solve_rust_scalar(&lp, &opts);
        assert!(
            rel_close(b.obj, s.obj, 5.0),
            "blocked {} vs scalar {}",
            b.obj,
            s.obj
        );
        // both are certified dual bounds for the same LP
        assert!(b.lower_bound <= b.obj + TOL * (1.0 + b.obj.abs()));
        assert!(s.lower_bound <= s.obj + TOL * (1.0 + s.obj.abs()));
    }
}
