//! Daemon-edge regression suite: the client deadline and the atomic
//! port-file write.
//!
//! Both are bugfix pins.  Before the deadline existed, a wedged daemon
//! (accepts the TCP connection, never replies) hung `hetsched status`
//! forever; `Client::call` must now fail within the configured timeout
//! with an error that says so.  Before the atomic write, the port file
//! was a plain `std::fs::write` — a reader racing the daemon could see
//! a torn prefix of the address; `write_file_atomic` goes through a
//! fsync'd `<path>.tmp` + rename, so the file is always either absent,
//! the old content, or the complete new content.

use std::io::Read;
use std::net::TcpListener;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use hetsched::service_net::{write_file_atomic, Client};
use hetsched::substrate::json::Json;

fn scratch_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("hetsched_daemon_edges").join(name);
    std::fs::create_dir_all(&dir).expect("scratch dir");
    dir
}

#[test]
fn wedged_daemon_times_out_instead_of_hanging() {
    // a listener that accepts and then never replies — the wedge the
    // default deadline exists for
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().unwrap().to_string();
    let wedge = std::thread::spawn(move || {
        let (stream, _) = listener.accept().expect("accept");
        // hold the connection open without ever replying, long enough
        // that only the client's deadline can end the call
        std::thread::sleep(Duration::from_secs(3));
        drop(stream);
    });

    let t0 = Instant::now();
    let mut client = Client::connect_with_timeout(&addr, 1).expect("connect succeeds");
    let err = client.status(0).expect_err("wedged daemon must not answer");
    let elapsed = t0.elapsed();
    assert!(
        err.contains("timeout"),
        "error must name the deadline, got: {err}"
    );
    assert!(
        elapsed < Duration::from_secs(8),
        "call returned only after {elapsed:?} — deadline not applied"
    );
    drop(client);
    wedge.join().ok();
}

#[test]
fn zero_timeout_disables_the_deadline() {
    // --timeout-s 0 must mean "no deadline" (the operator's escape
    // hatch for giant drains), not "fail immediately"
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().unwrap().to_string();
    let server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().expect("accept");
        // answer one frame after a pause longer than the default-ish
        // deadlines used in tests
        std::thread::sleep(Duration::from_millis(300));
        let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
        let frame = hetsched::service_net::wire::read_frame(&mut reader)
            .expect("read")
            .expect("one request");
        assert!(frame.get("op").is_some());
        let mut writer = stream;
        hetsched::service_net::wire::write_frame(
            &mut writer,
            &hetsched::service_net::wire::ok_response(vec![(
                "status",
                Json::obj(vec![("tenant", Json::Num(0.0))]),
            )]),
        )
        .expect("write");
    });
    let mut client = Client::connect_with_timeout(&addr, 0).expect("connect succeeds");
    let status = client.status(0).expect("slow but answering daemon");
    assert!(status.get("tenant").is_some());
    server.join().unwrap();
}

#[test]
fn atomic_write_leaves_no_tmp_and_full_content() {
    let dir = scratch_dir("atomic");
    let path = dir.join("port");
    write_file_atomic(&path, "127.0.0.1:7477").expect("first write");
    let mut s = String::new();
    std::fs::File::open(&path).unwrap().read_to_string(&mut s).unwrap();
    assert_eq!(s, "127.0.0.1:7477");
    assert!(
        !dir.join("port.tmp").exists(),
        "tmp sibling must be renamed away"
    );
    // overwrite: readers see old or new, and afterwards only new
    write_file_atomic(&path, "127.0.0.1:9000").expect("overwrite");
    let mut s = String::new();
    std::fs::File::open(&path).unwrap().read_to_string(&mut s).unwrap();
    assert_eq!(s, "127.0.0.1:9000");
    assert!(!dir.join("port.tmp").exists());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn atomic_write_reports_unwritable_targets() {
    let err = write_file_atomic(
        &PathBuf::from("/nonexistent-hetsched-dir/port"),
        "127.0.0.1:1",
    )
    .expect_err("missing parent directory must fail");
    assert!(
        err.contains("/nonexistent-hetsched-dir/port.tmp"),
        "error should name the tmp path: {err}"
    );
}
