//! Paper-facing regression suite: every table of the paper is pinned
//! here (Tables 1–5), plus the theorem drivers at test-sized grids.

use hetsched::experiments::thm;
use hetsched::workloads::{chameleon, forkjoin};

#[test]
fn table4_chameleon_counts_verbatim() {
    let expected: &[(&str, [usize; 3])] = &[
        ("getrf", [55, 385, 2870]),
        ("posv", [65, 330, 1960]),
        ("potrf", [35, 220, 1540]),
        ("potri", [105, 660, 4620]),
        ("potrs", [30, 110, 420]),
    ];
    let cm = hetsched::workloads::costs::CostModel::hybrid(320);
    for &(app, counts) in expected {
        for (i, &nb) in [5usize, 10, 20].iter().enumerate() {
            let g = chameleon::by_name(app, nb, &cm, 0).unwrap();
            assert_eq!(g.n_tasks(), counts[i], "{app} nb={nb}");
            g.validate().unwrap();
        }
    }
}

#[test]
fn table5_forkjoin_counts_verbatim() {
    let expected: &[(usize, [usize; 5])] = &[
        (2, [203, 403, 603, 803, 1003]),
        (5, [506, 1006, 1506, 2006, 2506]),
        (10, [1011, 2011, 3011, 4011, 5011]),
    ];
    for &(p, row) in expected {
        for (i, &w) in [100usize, 200, 300, 400, 500].iter().enumerate() {
            assert_eq!(forkjoin::forkjoin(w, p, 1, 1).n_tasks(), row[i]);
        }
    }
}

#[test]
fn table1_thm1_heft_ratio_grid() {
    for (m, k) in [(9usize, 2usize), (16, 4), (36, 4), (64, 8)] {
        let (_, _, ratio) = thm::thm1_run(m, k);
        let exact = thm::thm1_exact_ratio(m, k);
        assert!(
            (ratio - exact).abs() < 1e-6,
            "m={m},k={k}: {ratio} vs {exact}"
        );
    }
}

#[test]
fn table2_thm2_ratio_grid() {
    for m in [5usize, 20, 80] {
        let (lp_star, est, ols) = thm::thm2_run(m);
        let want = thm::thm2_worst_makespan(m) / lp_star;
        assert!((est - want).abs() < 1e-6);
        assert!((ols - want).abs() < 1e-6);
    }
    // asymptotically 6
    let (lp_star, est, _) = thm::thm2_run(200);
    assert!(est > 5.8 && est < 6.0, "ratio {est} (LP* {lp_star})");
}

#[test]
fn table3_thm4_ratio_grid() {
    for (m, k) in [(16usize, 4usize), (64, 16), (100, 4)] {
        let (_, _, ratio) = thm::thm4_run(m, k);
        let want = (m as f64 / k as f64).sqrt();
        assert!((ratio - want).abs() < 1e-9, "m={m},k={k}");
    }
}
