//! Fairness property suite for the service admission-control layer
//! (`sched::service::policy`): ~100 random multi-tenant draws ×
//! {FIFO, Quota, WeightedStretch} asserting, for every draw,
//!
//!   (a) quota-never-exceeded — an *independent replay* of the per-type
//!       held-units ledger (a unit is held from the decision that placed
//!       a task on it until the tenant's last reservation on it
//!       finishes) stays ≤ the tenant's cap at every decision time,
//!   (b) per-tenant precedence order preserved — each tenant's decisions
//!       appear in its stream (topological) order in the global decision
//!       stream, whatever the admission layer reorders across tenants,
//!   (c) every placement set passes the tenant-aware merge validator
//!       (`sim::validate_service`), and
//!   (d) single-tenant parity — a lone tenant's placements equal
//!       `sched::online` under every admission policy (full-share quota,
//!       any weight).
//!
//! Plus the deterministic interaction tests the admission layer owes the
//! cancellation path, and the contended-example acceptance pin:
//! WeightedStretch strictly reduces max stretch vs FIFO while FIFO's
//! placements stay bit-identical to the pre-policy reference path.

use hetsched::graph::gen;
use hetsched::graph::Builder;
use hetsched::platform::Platform;
use hetsched::sched::online::{online_schedule, random_topo_order, OnlinePolicy};
use hetsched::sched::reference;
use hetsched::sched::service::{run_service, Service, Submission, TenantPolicy};
use hetsched::sim::validate_service;
use hetsched::substrate::rng::Rng;

fn all_online_policies(seed: u64) -> Vec<OnlinePolicy> {
    vec![
        OnlinePolicy::ErLs,
        OnlinePolicy::Eft,
        OnlinePolicy::Greedy,
        OnlinePolicy::Random(seed),
        OnlinePolicy::R1,
        OnlinePolicy::R2,
        OnlinePolicy::R3,
    ]
}

/// Random admission policy of the requested kind (0 = FIFO, 1 = Quota,
/// 2 = WeightedStretch).
fn draw_admission(kind: usize, rng: &mut Rng) -> TenantPolicy {
    match kind {
        0 => TenantPolicy::Fifo,
        1 => {
            // occasionally ban one side outright (zero share)
            let cpu_share = match rng.below(4) {
                0 => 0.0,
                _ => 0.2 + 0.8 * rng.f64(),
            };
            let gpu_share = if cpu_share == 0.0 {
                0.2 + 0.8 * rng.f64()
            } else {
                match rng.below(4) {
                    0 => 0.0,
                    _ => 0.2 + 0.8 * rng.f64(),
                }
            };
            TenantPolicy::Quota { cpu_share, gpu_share }
        }
        _ => TenantPolicy::WeightedStretch { weight: 0.25 + 3.75 * rng.f64() },
    }
}

fn random_subs(rng: &mut Rng, draw: u64, kind: usize) -> (Platform, Vec<Submission>) {
    let plat = Platform::hybrid(1 + rng.below(6), 1 + rng.below(3));
    let policies = [
        OnlinePolicy::ErLs,
        OnlinePolicy::Eft,
        OnlinePolicy::Greedy,
        OnlinePolicy::Random(draw),
        OnlinePolicy::R2,
    ];
    let n_tenants = 2 + rng.below(4);
    let subs: Vec<Submission> = (0..n_tenants)
        .map(|t| {
            let n = 10 + rng.below(25);
            let g = gen::hybrid_dag(rng, n, 0.03 + 0.15 * rng.f64());
            let arrival = rng.f64() * 15.0;
            Submission::new(g, arrival, policies[(draw as usize + t) % policies.len()].clone())
                .with_admission(draw_admission(kind, rng))
        })
        .collect();
    (plat, subs)
}

/// Independent quota replay: for each quota tenant, at every decision
/// time in the run, count the distinct units of each type the tenant
/// holds (reservations with decision time ≤ t and finish > t) and
/// assert the count never exceeds the cap.
fn assert_quota_never_exceeded(
    plat: &Platform,
    subs: &[Submission],
    report: &hetsched::sched::service::ServiceReport,
    label: &str,
) {
    // decision time per (tenant, task)
    let mut decided_at: Vec<Vec<f64>> = subs
        .iter()
        .map(|s| vec![f64::NAN; s.graph.n_tasks()])
        .collect();
    for d in &report.decisions {
        decided_at[d.tenant][d.task] = d.time;
    }
    let event_times: Vec<f64> = report.decisions.iter().map(|d| d.time).collect();
    for (i, s) in subs.iter().enumerate() {
        let Some(caps) = s.admission.caps(plat) else {
            continue;
        };
        let t_rep = &report.tenants[i];
        for &t in &event_times {
            let mut held: Vec<std::collections::BTreeSet<usize>> =
                (0..plat.n_types()).map(|_| Default::default()).collect();
            for (&j, p) in t_rep.kept_tasks.iter().zip(&t_rep.schedule.placements) {
                let d = decided_at[i][j];
                if d <= t && p.finish > t {
                    held[p.ptype].insert(p.unit);
                }
            }
            for (q, h) in held.iter().enumerate() {
                assert!(
                    h.len() <= caps[q],
                    "{label}: tenant {i} holds {} units of type {q} at t={t} (cap {})",
                    h.len(),
                    caps[q]
                );
            }
        }
    }
}

/// Per-tenant precedence/stream order: each tenant's decisions appear in
/// its stream order (task-id order here) in the global stream.
fn assert_stream_order_preserved(
    subs: &[Submission],
    report: &hetsched::sched::service::ServiceReport,
    label: &str,
) {
    let mut next: Vec<usize> = vec![0; subs.len()];
    for d in &report.decisions {
        assert_eq!(
            d.task, next[d.tenant],
            "{label}: tenant {} decided out of stream order",
            d.tenant
        );
        next[d.tenant] += 1;
    }
    for (i, s) in subs.iter().enumerate() {
        assert_eq!(next[i], s.graph.n_tasks(), "{label}: tenant {i} incomplete");
    }
}

#[test]
fn fairness_invariants_on_random_draws_all_policies() {
    // 34 draws × 3 admission kinds ≈ 100 multi-tenant service runs
    let mut rng = Rng::new(0xFA1E);
    for draw in 0..34u64 {
        for kind in 0..3usize {
            let (plat, subs) = random_subs(&mut rng, draw, kind);
            let report = run_service(&plat, &subs);
            let label = format!("draw {draw} kind {kind}");
            validate_service(&plat, &report.tenant_runs(&subs))
                .unwrap_or_else(|e| panic!("{label}: {e}"));
            assert_stream_order_preserved(&subs, &report, &label);
            assert_quota_never_exceeded(&plat, &subs, &report, &label);
            // decision times never regress, even under WS reordering
            for w in report.decisions.windows(2) {
                assert!(w[0].time <= w[1].time, "{label}: decision times regressed");
            }
            // aggregates are consistent with the completed-stretch helper
            let stretches = report.completed_stretches();
            assert_eq!(stretches.len(), subs.len());
            let max = stretches.iter().fold(0.0f64, |a, &b| a.max(b));
            assert_eq!(report.max_stretch, max, "{label}");
            assert!(report.jain_index > 0.0 && report.jain_index <= 1.0 + 1e-12, "{label}");
            assert!(report.stretch_p99 <= report.max_stretch + 1e-12, "{label}");
        }
    }
}

#[test]
fn single_tenant_parity_with_online_under_every_policy() {
    // a lone tenant must place exactly like sched::online under FIFO,
    // full-share Quota and any WeightedStretch weight, for every online
    // policy (the admission layer is invisible without contention)
    let mut rng = Rng::new(0x51A7);
    for draw in 0..8u64 {
        let g = gen::hybrid_dag(&mut rng, 15 + rng.below(35), 0.1);
        let plat = Platform::hybrid(1 + rng.below(6), 1 + rng.below(3));
        let order = random_topo_order(&g, &mut rng);
        let weight = 0.25 + 3.75 * rng.f64();
        for policy in all_online_policies(draw) {
            let expect = online_schedule(&g, &plat, &order, &policy);
            for admission in [
                TenantPolicy::Fifo,
                TenantPolicy::Quota { cpu_share: 1.0, gpu_share: 1.0 },
                TenantPolicy::WeightedStretch { weight },
            ] {
                let subs = vec![Submission::new(g.clone(), 0.0, policy.clone())
                    .with_order(order.clone())
                    .with_admission(admission.clone())];
                let report = run_service(&plat, &subs);
                assert_eq!(
                    report.tenants[0].schedule.placements, expect.placements,
                    "draw {draw}: {} under {}",
                    policy.name(),
                    admission.name()
                );
                assert_eq!(report.tenants[0].stretch, 1.0);
            }
        }
    }
}

/// The contended example, scaled to test size (the full 50×1k version
/// lives in `benches/service_throughput.rs` and is gated by
/// `ci.sh --perf`): 12 tenants × 150 tasks on 6+2, staggered arrivals.
fn contended_subs(admission: fn(usize) -> TenantPolicy) -> (Platform, Vec<Submission>) {
    let plat = Platform::hybrid(6, 2);
    let policies = [OnlinePolicy::ErLs, OnlinePolicy::Eft, OnlinePolicy::Greedy];
    let mut rng = Rng::new(2027);
    let subs: Vec<Submission> = (0..12)
        .map(|t| {
            let g = gen::hybrid_dag(&mut rng, 150, 0.03);
            Submission::new(g, t as f64 * 5.0, policies[t % policies.len()].clone())
                .with_admission(admission(t))
        })
        .collect();
    (plat, subs)
}

#[test]
fn weighted_stretch_strictly_reduces_max_stretch_on_contended_example() {
    // acceptance pin: on the contended example, equal-weight
    // WeightedStretch strictly beats FIFO on max stretch, and FIFO's
    // placements stay bit-identical to the pre-policy reference path
    let (plat, fifo_subs) = contended_subs(|_| TenantPolicy::Fifo);
    let fifo = run_service(&plat, &fifo_subs);
    let golden = reference::run_service(&plat, &fifo_subs);
    for (i, t) in fifo.tenants.iter().enumerate() {
        assert_eq!(
            t.schedule.placements, golden[i].placements,
            "tenant {i}: FIFO drifted from the pre-policy service path"
        );
    }

    let (_, ws_subs) = contended_subs(|_| TenantPolicy::WeightedStretch { weight: 1.0 });
    let ws = run_service(&plat, &ws_subs);
    validate_service(&plat, &ws.tenant_runs(&ws_subs)).unwrap();
    assert!(
        ws.max_stretch < fifo.max_stretch,
        "WeightedStretch must strictly reduce max stretch: {} vs FIFO {}",
        ws.max_stretch,
        fifo.max_stretch
    );
}

#[test]
fn latency_metric_never_feeds_placement() {
    // Pin for the decision-latency contract: the service core contains
    // *zero* wall-clock reads (hetlint R4, no suppressions); latency is
    // injected only at a runtime edge via `Service::note_decision_latency`.
    // Run A of the contended 12×150 example injects a wildly varying
    // synthetic latency after every decision; run B injects none.  If
    // the metric — or anything derived from it — ever leaked into
    // placement, admission or tie-breaking, the runs would drift.
    // Everything except the latency summaries must be bit-identical.
    fn mixed(t: usize) -> TenantPolicy {
        match t % 3 {
            0 => TenantPolicy::Fifo,
            1 => TenantPolicy::Quota { cpu_share: 0.5, gpu_share: 0.5 },
            _ => TenantPolicy::WeightedStretch { weight: 1.0 + t as f64 },
        }
    }
    let (plat, subs_a) = contended_subs(mixed);
    let (_, subs_b) = contended_subs(mixed);

    let mut svc = Service::new(&plat, &subs_a);
    let mut injected = 0u64;
    while let Some(d) = svc.step() {
        // adversarial edge measurements: vary by decision index
        svc.note_decision_latency(d.tenant, 1e-6 * (1.0 + (injected % 17) as f64));
        injected += 1;
    }
    let a = svc.report(None);
    let b = run_service(&plat, &subs_b);

    assert_eq!(a.decisions.len(), b.decisions.len(), "decision counts drifted");
    assert_eq!(injected, a.decisions.len() as u64);
    for (da, db) in a.decisions.iter().zip(&b.decisions) {
        assert_eq!((da.tenant, da.task), (db.tenant, db.task), "decision order drifted");
        assert_eq!(da.time.to_bits(), db.time.to_bits(), "decision time drifted across runs");
    }
    assert_eq!(a.horizon.to_bits(), b.horizon.to_bits());
    assert_eq!(a.total_tasks, b.total_tasks);
    assert_eq!(a.max_stretch.to_bits(), b.max_stretch.to_bits());
    assert_eq!(a.stretch_p99.to_bits(), b.stretch_p99.to_bits());
    assert_eq!(a.jain_index.to_bits(), b.jain_index.to_bits());
    for (ta, tb) in a.tenants.iter().zip(&b.tenants) {
        assert_eq!(
            ta.schedule.placements, tb.schedule.placements,
            "tenant {}: placements depend on the injected latencies",
            ta.tenant
        );
        assert_eq!(ta.stretch.to_bits(), tb.stretch.to_bits());
        // run A's metric carries the edge injections, once per decision;
        // run B (batch, no edge) records none
        assert_eq!(ta.decision_latency.n, ta.n_placed);
        assert_eq!(tb.decision_latency.n, 0);
    }
}

#[test]
fn cancelling_a_quota_capped_tenant_frees_its_share() {
    // 1 CPU + 1 GPU; tenant 0 (cap: 1 CPU) stacks two chain tasks on the
    // CPU, [0,10) then [10,20), and is cancelled at t=10 before the
    // second starts: the trailing reservation is rewound, so tenant 1 —
    // itself CPU-capped — reuses the freed capacity at its own arrival
    // (15) instead of queueing behind the ghost until 20
    let chain = |dur: f64| {
        let mut b = Builder::new("c");
        let a = b.add_task("a", vec![dur, dur * 100.0]);
        let c = b.add_task("b", vec![dur, dur * 100.0]);
        b.add_arc(a, c);
        b.build()
    };
    let one = || {
        let mut b = Builder::new("one");
        b.add_task("t", vec![1.0, 100.0]);
        b.build()
    };
    let plat = Platform::hybrid(1, 1);
    let subs = vec![
        Submission::new(chain(10.0), 0.0, OnlinePolicy::Greedy)
            .with_admission(TenantPolicy::Quota { cpu_share: 1.0, gpu_share: 0.0 }),
        Submission::new(one(), 15.0, OnlinePolicy::Greedy)
            .with_admission(TenantPolicy::Quota { cpu_share: 1.0, gpu_share: 0.0 }),
    ];
    let mut svc = Service::new(&plat, &subs);
    assert!(svc.step().is_some()); // t0/a on CPU [0, 10)
    assert!(svc.step().is_some()); // t0/b on CPU [10, 20), decided at 10
    let out = svc.cancel(0);
    assert_eq!(out.at, 10.0);
    assert_eq!(out.dropped_tasks, 1, "trailing not-yet-started task rewound");
    assert_eq!(out.released_units, 1);
    svc.run();
    let report = svc.report(None);
    assert_eq!(report.tenants[0].n_placed, 1, "running task kept");
    // the survivor starts at its own arrival, not behind the ghost
    assert_eq!(report.tenants[1].schedule.placements[0].start, 15.0);
    assert_eq!(report.tenants[1].stretch, 1.0);
    validate_service(&plat, &report.tenant_runs(&subs)).unwrap();
}

#[test]
fn cancellation_under_weighted_stretch_recomputes_ordering() {
    // three WS tenants on one CPU; the one whose stretch would dominate
    // the reordering is cancelled mid-run: already-started tasks stay
    // untouched (bit-identical prefix), the survivors' remaining
    // admissions re-rank among themselves, and everything stays feasible
    let chain = |app: &str, len: usize, dur: f64| {
        let mut b = Builder::new(app);
        let mut prev = None;
        for _ in 0..len {
            let t = b.add_task("t", vec![dur, dur * 100.0]);
            if let Some(p) = prev {
                b.add_arc(p, t);
            }
            prev = Some(t);
        }
        b.build()
    };
    let hog = || {
        let mut b = Builder::new("hog");
        b.add_task("t", vec![10000.0, 100.0]);
        b.build()
    };
    let plat = Platform::hybrid(1, 1);
    // tenant 0 hogs the GPU so the pool saturates and weighted-stretch
    // windows actually open on the CPU side; tenants 1–3 compete there
    let subs: Vec<Submission> = std::iter::once(
        Submission::new(hog(), 0.0, OnlinePolicy::Greedy)
            .with_admission(TenantPolicy::WeightedStretch { weight: 1.0 }),
    )
    .chain((1..4).map(|t| {
        Submission::new(chain(&format!("t{t}"), 4, 1.0 + t as f64), 0.0, OnlinePolicy::Greedy)
            .with_admission(TenantPolicy::WeightedStretch { weight: 1.0 })
    }))
    .collect();

    let mut svc = Service::new(&plat, &subs);
    let mut prefix = Vec::new();
    for _ in 0..6 {
        prefix.push(svc.step().unwrap());
    }
    let victim = 1usize;
    let pre_cancel_tasks: Vec<usize> = prefix
        .iter()
        .filter(|d| d.tenant == victim)
        .map(|d| d.task)
        .collect();
    let _ = svc.cancel(victim);
    svc.run();
    let report = svc.report(None);
    // already-started tasks untouched: the victim's kept tasks are a
    // subset of what was decided before the cancel — nothing re-placed
    for &j in &report.tenants[victim].kept_tasks {
        assert!(pre_cancel_tasks.contains(&j), "kept task {j} was not pre-cancel");
    }
    // survivors complete and in stream order (the remaining WS heads
    // re-rank among themselves only)
    assert_eq!(report.tenants[0].n_placed, 1);
    for i in 2..4 {
        assert_eq!(report.tenants[i].n_placed, 4, "tenant {i}");
    }
    let mut next = vec![0usize; 4];
    for d in &report.decisions {
        assert_eq!(d.task, next[d.tenant], "stream order broken after cancel");
        next[d.tenant] += 1;
    }
    for w in report.decisions.windows(2) {
        assert!(w[0].time <= w[1].time, "decision times regressed after cancel");
    }
    hetsched::sim::validate_placements_no_overlap(
        report.tenants.iter().flat_map(|t| &t.schedule.placements),
    )
    .unwrap();
    validate_service(&plat, &report.tenant_runs(&subs)).unwrap();
}
