//! Integration tests across layers: workloads → LP (both backends) →
//! rounding → scheduling → validation → analysis, plus the live
//! coordinator, on real benchmark instances.

use hetsched::algos::{run_offline, solve_hlp, solve_qhlp, Offline};
use hetsched::analysis::{pairwise_by_app, ratio_by_app, Record};
use hetsched::coordinator::{run_live, LiveConfig};
use hetsched::experiments::cache::{cache_key, LpCache};
use hetsched::platform::Platform;
use hetsched::runtime::{with_runtime, LpBackendKind};
use hetsched::sched::online::{online_by_id, OnlinePolicy};
use hetsched::sim::{validate, validate_realized};
use hetsched::workloads::{chameleon, costs::CostModel, forkjoin, instances, Instance, Scale};

fn artifacts_present() -> bool {
    hetsched::runtime::artifacts_dir().join("manifest.json").exists()
}

#[test]
fn every_smoke_instance_schedules_feasibly_with_all_algorithms() {
    let plat = Platform::hybrid(16, 4);
    for inst in instances(Scale::Smoke) {
        let g = inst.generate(2);
        let hlp = solve_hlp(&g, &plat, LpBackendKind::RustPdhg, 1e-4);
        for algo in Offline::ALL {
            let (s, _) =
                run_offline(algo, &g, &plat, Some(&hlp), LpBackendKind::RustPdhg, 1e-4);
            validate(&g, &plat, &s)
                .unwrap_or_else(|e| panic!("{} on {}: {e}", algo.name(), inst.label()));
            assert!(s.makespan >= hlp.sol.obj * 0.99);
            assert!(s.makespan <= 6.0 * hlp.sol.obj * 1.02);
        }
        for policy in [OnlinePolicy::ErLs, OnlinePolicy::Eft, OnlinePolicy::Greedy] {
            let s = online_by_id(&g, &plat, &policy);
            validate(&g, &plat, &s).unwrap();
        }
    }
}

#[test]
fn pjrt_and_rust_backends_agree_on_benchmark_lps() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let plat = Platform::hybrid(16, 4);
    for inst in [
        Instance::Chameleon {
            app: "potrf".into(),
            nb_blocks: 10,
            block_size: 320,
        },
        Instance::ForkJoin {
            width: 100,
            phases: 2,
        },
    ] {
        let g = inst.generate(2);
        let a = solve_hlp(&g, &plat, LpBackendKind::Pjrt, 1e-4);
        let b = solve_hlp(&g, &plat, LpBackendKind::RustPdhg, 1e-4);
        assert_eq!(a.sol.backend, "pdhg-pjrt");
        assert_eq!(b.sol.backend, "pdhg-rust");
        let scale = 1.0 + a.sol.obj.abs().max(b.sol.obj.abs());
        assert!(
            (a.sol.obj - b.sol.obj).abs() / scale < 5e-3,
            "{}: pjrt {} vs rust {}",
            inst.label(),
            a.sol.obj,
            b.sol.obj
        );
        // allocations need not be identical (alternative optima) but
        // both must produce feasible, certified schedules
        for lp in [&a, &b] {
            let (s, _) = run_offline(
                Offline::HlpOls,
                &g,
                &plat,
                Some(lp),
                LpBackendKind::RustPdhg,
                1e-4,
            );
            validate(&g, &plat, &s).unwrap();
            assert!(s.makespan <= 6.0 * lp.sol.obj * 1.02);
        }
    }
}

#[test]
fn simplex_backend_matches_pdhg_on_small_instance() {
    let g = chameleon::potrf(5, &CostModel::hybrid(320), 3);
    let plat = Platform::hybrid(4, 2);
    let exact = solve_hlp(&g, &plat, LpBackendKind::Simplex, 1e-4);
    let approx = solve_hlp(&g, &plat, LpBackendKind::RustPdhg, 1e-6);
    assert_eq!(exact.sol.backend, "simplex");
    assert!((exact.sol.obj - approx.sol.obj).abs() / (1.0 + exact.sol.obj) < 5e-3);
}

#[test]
fn three_type_pipeline_on_forkjoin() {
    let g = forkjoin::forkjoin(50, 2, 2, 9);
    assert_eq!(g.n_types(), 3);
    let plat = Platform::new(vec![8, 2, 2]);
    let qhlp = solve_qhlp(&g, &plat, LpBackendKind::RustPdhg, 1e-4);
    for algo in Offline::ALL {
        let (s, _) = run_offline(algo, &g, &plat, Some(&qhlp), LpBackendKind::RustPdhg, 1e-4);
        validate(&g, &plat, &s).unwrap();
        assert!(s.makespan <= 12.0 * qhlp.sol.obj * 1.02); // Q(Q+1) = 12
    }
}

#[test]
fn lp_cache_roundtrip_through_campaign_shape() {
    let dir = std::env::temp_dir().join(format!("hetsched-it-{}", std::process::id()));
    let path = dir.join("cache.json");
    let g = chameleon::potrs(5, &CostModel::hybrid(128), 4);
    let plat = Platform::hybrid(16, 2);
    let solved = solve_hlp(&g, &plat, LpBackendKind::RustPdhg, 1e-4);
    let key = cache_key("potrs-nb5-bs128", &plat.label(), 2, 1e-4, 80_000);
    let mut cache = LpCache::default();
    cache.put(&key, &solved);
    cache.save(&path).unwrap();
    let reloaded = LpCache::load(&path);
    let got = reloaded.get(&key).unwrap();
    assert_eq!(got.alloc, solved.alloc);
    assert!((got.sol.obj - solved.sol.obj).abs() < 1e-12);
    // the cached allocation schedules identically
    let s1 = hetsched::sched::est::est_schedule(&g, &plat, &solved.alloc);
    let s2 = hetsched::sched::est::est_schedule(&g, &plat, &got.alloc);
    assert_eq!(s1.makespan, s2.makespan);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn analysis_pipeline_produces_paper_shaped_outputs() {
    // miniature campaign by hand: 2 instances x 1 config x 3 algos
    let plat = Platform::hybrid(16, 4);
    let mut records = Vec::new();
    for inst in [
        Instance::Chameleon {
            app: "posv".into(),
            nb_blocks: 5,
            block_size: 320,
        },
        Instance::ForkJoin {
            width: 100,
            phases: 2,
        },
    ] {
        let g = inst.generate(2);
        let hlp = solve_hlp(&g, &plat, LpBackendKind::RustPdhg, 1e-4);
        for algo in Offline::ALL {
            let (s, _) =
                run_offline(algo, &g, &plat, Some(&hlp), LpBackendKind::RustPdhg, 1e-4);
            records.push(Record {
                instance: inst.label(),
                app: inst.app().to_string(),
                config: plat.label(),
                algo: algo.name().to_string(),
                makespan: s.makespan,
                lp_star: hlp.sol.obj,
                sqrt_mk: 2.0,
            });
        }
    }
    let by_app = ratio_by_app(&records, "HLP-OLS");
    assert_eq!(by_app.len(), 2);
    for s in by_app.values() {
        assert!(s.mean >= 1.0 * 0.99 && s.mean <= 6.0);
    }
    let pw = pairwise_by_app(&records, "HLP-EST", "HLP-OLS");
    assert_eq!(pw.len(), 2);
}

#[test]
fn live_coordinator_matches_engine_on_real_workload() {
    let g = chameleon::potrf(5, &CostModel::hybrid(960), 6);
    let plat = Platform::hybrid(3, 2);
    let order: Vec<usize> = (0..g.n_tasks()).collect();
    let cfg = LiveConfig {
        time_scale: 0.05 / (0..g.n_tasks()).map(|j| g.p_cpu(j)).sum::<f64>(),
        policy: OnlinePolicy::ErLs,
    };
    let (report, realized) = run_live(&g, &plat, &order, &cfg);
    validate_realized(&g, &plat, &realized).unwrap();
    assert_eq!(
        realized.allocation(),
        online_by_id(&g, &plat, &OnlinePolicy::ErLs).allocation(),
        "live run must take identical irrevocable decisions"
    );
    assert!(report.realized_makespan >= report.predicted_makespan * 0.95);
}

#[test]
fn pjrt_full_pipeline_on_small_instance() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let g = chameleon::getrf(5, &CostModel::hybrid(512), 8);
    let plat = Platform::hybrid(8, 2);
    let done = with_runtime(|rt| {
        let (mut lp, vars) = hetsched::lp::model::build_hlp(&g, &plat);
        let warm = hetsched::lp::model::hlp_warm_start(
            &g,
            &plat,
            &hetsched::alloc::greedy_min_time(&g),
            &vars,
        );
        hetsched::lp::model::tighten_hlp_box(&mut lp, &vars, warm[vars.lambda]);
        let sol = rt
            .solve(
                &lp,
                &hetsched::lp::pdhg::DriveOpts {
                    tol: 1e-4,
                    warm_start: Some(warm),
                    ..Default::default()
                },
            )
            .expect("pjrt solve");
        assert!(rt.total_chunks > 0);
        let alloc = hetsched::lp::rounding::round_hlp(&sol.z, &vars);
        let s = hetsched::sched::list::ols_schedule(&g, &plat, &alloc);
        validate(&g, &plat, &s).unwrap();
        assert!(s.makespan <= 6.0 * sol.obj * 1.02);
    });
    assert!(done.is_some());
}
