//! Observability non-perturbation suite: the tentpole invariant of the
//! obs layer is that *tracing is free of behavioral consequence* —
//! running any scheduler with a [`RecordingSink`] attached produces
//! placements bitwise identical to the untraced run, and the recorded
//! stream itself is a deterministic function of the workload (two runs
//! emit byte-identical JSONL).
//!
//! Coverage mirrors the two seed matrices the repo already pins:
//!
//! * the golden-parity sweep (random `hybrid_dag` draws × random
//!   platforms through EST / OLS / list / HEFT / every online policy),
//!   re-run here traced-vs-untraced with `to_bits` placement equality;
//! * the service-fairness draw generator (multi-tenant streams ×
//!   {FIFO, Quota, WeightedStretch}), re-run traced-vs-untraced through
//!   the full report aggregates;
//!
//! plus the daemon-side contracts: WAL replay re-emits the original
//! run's core event stream exactly, edge metrics accumulate without
//! entering the replay-stable report, and `explain` renders a stable,
//! correct decision story from a seeded WAL.

use std::path::PathBuf;

use hetsched::graph::gen;
use hetsched::obs::event::to_jsonl;
use hetsched::obs::{EventKind, RecordingSink};
use hetsched::platform::Platform;
use hetsched::sched::online::{
    online_schedule, online_schedule_traced, random_topo_order, OnlinePolicy,
};
use hetsched::sched::service::{run_service, Service, Submission, TenantPolicy};
use hetsched::sched::{est, heft, list};
use hetsched::service_net::{explain_from_wal, Core};
use hetsched::sim::{Placement, Schedule};
use hetsched::substrate::rng::Rng;

const CASES: usize = 25;

fn random_platform(rng: &mut Rng) -> Platform {
    let k = 1 + rng.below(6);
    let m = 1 + rng.below(16);
    Platform::hybrid(m.max(k), k)
}

fn speed_alloc(g: &hetsched::graph::TaskGraph) -> Vec<usize> {
    (0..g.n_tasks())
        .map(|j| usize::from(g.p_gpu(j) < g.p_cpu(j)))
        .collect()
}

/// Bitwise schedule equality — the non-perturbation pin is about bits,
/// not `==` (a `-0.0` drift must not hide behind IEEE equality).
fn assert_bitwise_eq(a: &Schedule, b: &Schedule, label: &str) {
    assert_eq!(a.placements.len(), b.placements.len(), "{label}: lengths");
    for (j, (pa, pb)) in a.placements.iter().zip(&b.placements).enumerate() {
        let eq = pa.ptype == pb.ptype
            && pa.unit == pb.unit
            && pa.start.to_bits() == pb.start.to_bits()
            && pa.finish.to_bits() == pb.finish.to_bits();
        assert!(eq, "{label}: task {j} diverged: {pa:?} vs {pb:?}");
    }
    assert_eq!(
        a.makespan.to_bits(),
        b.makespan.to_bits(),
        "{label}: makespan bits"
    );
}

fn n_decisions(events: &[hetsched::obs::Event]) -> usize {
    events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::Decision(_)))
        .count()
}

#[test]
fn offline_engines_traced_match_untraced_bitwise() {
    let mut rng = Rng::new(0x0B5_0001);
    for case in 0..CASES {
        let n = 30 + rng.below(100);
        let g = gen::hybrid_dag(&mut rng, n, 0.02 + 0.13 * rng.f64());
        let plat = random_platform(&mut rng);
        let alloc = speed_alloc(&g);
        let prio: Vec<f64> = (0..n).map(|_| rng.f64()).collect();

        let mut sink = RecordingSink::new();
        let traced = est::est_schedule_traced(&g, &plat, &alloc, &mut sink);
        let plain = est::est_schedule(&g, &plat, &alloc);
        assert_bitwise_eq(&traced, &plain, &format!("EST case {case}"));
        assert_eq!(n_decisions(sink.events()), n, "EST decision span per task");

        let mut sink = RecordingSink::new();
        let traced = list::list_schedule_traced(&g, &plat, &alloc, &prio, &mut sink);
        let plain = list::list_schedule(&g, &plat, &alloc, &prio);
        assert_bitwise_eq(&traced, &plain, &format!("list case {case}"));
        assert_eq!(n_decisions(sink.events()), n, "list decision span per task");

        let mut sink = RecordingSink::new();
        let traced = heft::heft_schedule_traced(&g, &plat, &mut sink);
        let plain = heft::heft_schedule(&g, &plat);
        assert_bitwise_eq(&traced, &plain, &format!("HEFT case {case}"));
        assert_eq!(n_decisions(sink.events()), n, "HEFT decision span per task");
        assert!(
            sink.events()
                .iter()
                .any(|e| matches!(e.kind, EventKind::GapProbe { .. })),
            "HEFT trace carries gap-index probes (case {case})"
        );
    }
}

#[test]
fn online_policies_traced_match_untraced_bitwise() {
    let mut rng = Rng::new(0x0B5_0002);
    for case in 0..CASES {
        let n = 30 + rng.below(100);
        let g = gen::hybrid_dag(&mut rng, n, 0.02 + 0.13 * rng.f64());
        let plat = random_platform(&mut rng);
        let order = random_topo_order(&g, &mut rng);
        for policy in [
            OnlinePolicy::ErLs,
            OnlinePolicy::Eft,
            OnlinePolicy::Greedy,
            OnlinePolicy::Random(case as u64),
            OnlinePolicy::R1,
            OnlinePolicy::R2,
            OnlinePolicy::R3,
        ] {
            let mut sink = RecordingSink::new();
            let traced = online_schedule_traced(&g, &plat, &order, &policy, &mut sink);
            let plain = online_schedule(&g, &plat, &order, &policy);
            assert_bitwise_eq(
                &traced,
                &plain,
                &format!("{} case {case}", policy.name()),
            );
            assert_eq!(
                n_decisions(sink.events()),
                n,
                "{} emits one decision span per task",
                policy.name()
            );
        }
    }
}

/// The service-fairness draw generator, reproduced (same shapes, its
/// own seeds) so tracing is exercised across FIFO/Quota/WeightedStretch
/// admission, quota bans, and cancellation-free multi-tenant streams.
fn service_draw(rng: &mut Rng, draw: u64, kind: usize) -> (Platform, Vec<Submission>) {
    let plat = Platform::hybrid(1 + rng.below(6), 1 + rng.below(3));
    let policies = [
        OnlinePolicy::ErLs,
        OnlinePolicy::Eft,
        OnlinePolicy::Greedy,
        OnlinePolicy::Random(draw),
        OnlinePolicy::R2,
    ];
    let n_tenants = 2 + rng.below(4);
    let subs: Vec<Submission> = (0..n_tenants)
        .map(|t| {
            let n = 10 + rng.below(25);
            let g = gen::hybrid_dag(rng, n, 0.03 + 0.15 * rng.f64());
            let arrival = rng.f64() * 15.0;
            let admission = match kind {
                0 => TenantPolicy::Fifo,
                1 => TenantPolicy::Quota {
                    cpu_share: 0.2 + 0.8 * rng.f64(),
                    gpu_share: 0.2 + 0.8 * rng.f64(),
                },
                _ => TenantPolicy::WeightedStretch { weight: 0.25 + 3.75 * rng.f64() },
            };
            Submission::new(g, arrival, policies[(draw as usize + t) % policies.len()].clone())
                .with_admission(admission)
        })
        .collect();
    (plat, subs)
}

#[test]
fn service_tracing_never_perturbs_placements_or_report() {
    let mut rng = Rng::new(0x0B5_0003);
    for kind in 0..3usize {
        for draw in 0..12u64 {
            let (plat, subs) = service_draw(&mut rng, draw, kind);

            let mut traced_svc = Service::new(&plat, &subs);
            traced_svc.enable_trace();
            traced_svc.run();
            let events = traced_svc.take_trace();
            let traced = traced_svc.report(None);
            let plain = run_service(&plat, &subs);

            let label = format!("kind {kind} draw {draw}");
            assert_eq!(
                traced.decisions.len(),
                plain.decisions.len(),
                "{label}: decision count"
            );
            for (a, b) in traced.decisions.iter().zip(&plain.decisions) {
                assert_eq!((a.tenant, a.task), (b.tenant, b.task), "{label}");
                assert_eq!(a.time.to_bits(), b.time.to_bits(), "{label}");
            }
            for (i, (ta, tb)) in traced.tenants.iter().zip(&plain.tenants).enumerate() {
                assert_bitwise_eq(
                    &ta.schedule,
                    &tb.schedule,
                    &format!("{label} tenant {i}"),
                );
                assert_eq!(ta.stretch.to_bits(), tb.stretch.to_bits(), "{label}");
                assert_eq!(ta.flow_time.to_bits(), tb.flow_time.to_bits(), "{label}");
            }
            assert_eq!(traced.horizon.to_bits(), plain.horizon.to_bits(), "{label}");
            assert_eq!(
                traced.mean_stretch.to_bits(),
                plain.mean_stretch.to_bits(),
                "{label}"
            );
            assert_eq!(
                traced.jain_index.to_bits(),
                plain.jain_index.to_bits(),
                "{label}"
            );
            // the always-on summaries are sink-independent too
            assert_eq!(traced.rule_counts, plain.rule_counts, "{label}");
            assert_eq!(
                traced.restricted_decisions, plain.restricted_decisions,
                "{label}"
            );
            assert_eq!(
                n_decisions(&events),
                traced.decisions.len(),
                "{label}: one decision span per placement"
            );
        }
    }
}

#[test]
fn trace_jsonl_is_byte_identical_across_runs() {
    let mut seeds = Rng::new(0x0B5_0004);
    for kind in 0..3usize {
        let mut rng_a = Rng::new(0xD15C_0000 + kind as u64);
        let mut rng_b = Rng::new(0xD15C_0000 + kind as u64);
        let (plat_a, subs_a) = service_draw(&mut rng_a, 7, kind);
        let (plat_b, subs_b) = service_draw(&mut rng_b, 7, kind);

        let run = |plat: &Platform, subs: &[Submission]| {
            let mut svc = Service::new(plat, subs);
            svc.enable_trace();
            svc.run();
            to_jsonl(&svc.take_trace())
        };
        let a = run(&plat_a, &subs_a);
        let b = run(&plat_b, &subs_b);
        assert!(!a.is_empty(), "kind {kind}: trace is non-empty");
        assert_eq!(a, b, "kind {kind}: two runs write byte-identical JSONL");
    }

    // and the offline entry points: same draw, two traced runs
    let n = 40 + seeds.below(40);
    let g = gen::hybrid_dag(&mut seeds, n, 0.08);
    let plat = random_platform(&mut seeds);
    let order: Vec<usize> = (0..n).collect();
    let mut s1 = RecordingSink::new();
    let mut s2 = RecordingSink::new();
    online_schedule_traced(&g, &plat, &order, &OnlinePolicy::ErLs, &mut s1);
    online_schedule_traced(&g, &plat, &order, &OnlinePolicy::ErLs, &mut s2);
    assert_eq!(to_jsonl(s1.events()), to_jsonl(s2.events()));
}

fn scratch_wal(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("hetsched_obs_parity");
    std::fs::create_dir_all(&dir).expect("scratch dir");
    let path = dir.join(name);
    let _ = std::fs::remove_file(&path);
    path
}

/// One small contended workload driven through the daemon [`Core`]
/// (tracing on), leaving a WAL behind for the replay-side tests.
fn seeded_core(name: &str) -> (PathBuf, Platform, Core, Vec<hetsched::obs::Event>) {
    let path = scratch_wal(name);
    let plat = Platform::hybrid(3, 1);
    let (mut core, replay) = Core::open(&path, &plat).expect("fresh wal opens");
    assert_eq!(replay.ops, 0);
    core.enable_trace();
    let mut rng = Rng::new(0x5EED_0001);
    let mut events = Vec::new();
    for t in 0..3usize {
        let g = gen::hybrid_dag(&mut rng, 12 + 4 * t, 0.1);
        let sub = Submission::new(g, t as f64 * 2.0, OnlinePolicy::Eft)
            .with_admission(TenantPolicy::Fifo);
        core.submit(sub).expect("submit");
        events.extend(core.take_trace());
    }
    core.report().expect("drain + report");
    events.extend(core.take_trace());
    (path, plat, core, events)
}

#[test]
fn wal_replay_reemits_the_original_core_event_stream() {
    let (path, plat, core, original) = seeded_core("replay_trace.wal");
    assert!(n_decisions(&original) > 0, "seed run decided something");
    assert!(
        original
            .iter()
            .any(|e| matches!(e.kind, EventKind::Wal { op: "append", .. })),
        "daemon trace interleaves WAL append events"
    );
    assert!(
        original
            .iter()
            .any(|e| matches!(e.kind, EventKind::Wal { op: "fsync", .. })),
        "daemon trace interleaves WAL fsync events"
    );
    drop(core);

    // offline replay re-runs the logged ops through a fresh tracing
    // Service; its core events (everything but the daemon-edge Wal
    // records) must reproduce the original stream exactly
    let mut svc = Service::empty(&plat);
    svc.enable_trace();
    let scan = hetsched::service_net::wal::recover(&path).expect("recover");
    for rec in &scan.records[1..] {
        match rec {
            hetsched::service_net::wal::WalRecord::Submit { sub } => {
                svc.admit(sub.clone()).expect("replay admit");
            }
            hetsched::service_net::wal::WalRecord::Drain => svc.run(),
            hetsched::service_net::wal::WalRecord::Decision { .. } => {}
            other => panic!("unexpected record {other:?}"),
        }
    }
    let replayed = svc.take_trace();
    let core_only: Vec<(u64, &hetsched::obs::EventKind)> = original
        .iter()
        .filter(|e| !matches!(e.kind, EventKind::Wal { .. }))
        .map(|e| (e.vtime.to_bits(), &e.kind))
        .collect();
    let replay_view: Vec<(u64, &hetsched::obs::EventKind)> =
        replayed.iter().map(|e| (e.vtime.to_bits(), &e.kind)).collect();
    assert_eq!(
        core_only, replay_view,
        "replay re-emits the original core event stream"
    );
}

#[test]
fn explain_is_stable_and_matches_the_decided_placement() {
    let (path, _plat, mut core, _events) = seeded_core("explain.wal");
    let d = core.decisions()[0];
    // the placement the daemon actually took for that decision
    let svc_report = core.report().expect("report");
    let place: Placement = svc_report.tenants[d.tenant]
        .kept_tasks
        .iter()
        .zip(&svc_report.tenants[d.tenant].schedule.placements)
        .find(|(&j, _)| j == d.task)
        .map(|(_, p)| *p)
        .expect("decided task has a placement");

    let once = explain_from_wal(&path, d.tenant, d.task).expect("explain");
    let twice = explain_from_wal(&path, d.tenant, d.task).expect("explain again");
    assert_eq!(once, twice, "explain output is byte-stable across replays");

    assert!(once.starts_with(&format!("task {}:{} — policy EFT", d.tenant, d.task)));
    assert!(
        once.contains(&format!(
            "placed: type {} unit {} start {} finish {}",
            place.ptype, place.unit, place.start, place.finish
        )),
        "explain reports the placement the daemon actually took:\n{once}"
    );
    assert!(once.contains("rule: eft — EFT: minimized finish time"));
    assert!(once.contains("candidates considered:"));
    assert!(once.contains("stream-heap depth at decision:"));

    let missing = explain_from_wal(&path, 0, 10_000).unwrap_err();
    assert!(missing.contains("no decision recorded"), "{missing}");
    let bad_tenant = explain_from_wal(&path, 99, 0).unwrap_err();
    assert!(bad_tenant.contains("no tenant 99"), "{bad_tenant}");
}

#[test]
fn daemon_edge_metrics_accumulate_outside_the_replay_stable_report() {
    let (_path, _plat, core, _events) = seeded_core("metrics.wal");
    let n_decided = core.decisions().len() as u64;
    let mut core = core;
    let report = core.report().expect("report");
    let snap = core.metrics();

    // core registry: pure functions of the op stream
    assert_eq!(snap.counters["svc_tenants"], 3);
    assert!(snap.counters["svc_decisions"] >= n_decided);
    let rule_total: u64 = snap
        .counters
        .iter()
        .filter(|(k, _)| k.starts_with("svc_rule_"))
        .map(|(_, &v)| v)
        .sum();
    assert_eq!(
        rule_total, snap.counters["svc_decisions"],
        "every decision is attributed to exactly one rule"
    );

    // edge registry: WAL accounting + the edge latency histogram
    assert!(snap.counters["wal_appends"] > 0);
    assert!(snap.counters["wal_bytes"] > 0);
    assert!(snap.counters["wal_syncs"] > 0);
    let lat = snap.hists.get("edge_decision_latency_s").expect("edge histogram");
    assert_eq!(
        lat.total(),
        snap.counters["svc_decisions"],
        "one edge latency sample per decision"
    );

    // ... and none of it leaks into the replay-stable wire report: the
    // report's only latency surface is the per-tenant Summary fed by
    // note_edge_latency, never a placement input (the fairness suite
    // pins that), and report_to_json drops it entirely.
    let j = hetsched::service_net::wire::report_to_json(&report);
    assert!(j.get("decision_latency").is_none());
    for t in &report.tenants {
        assert_eq!(
            t.decision_latency.n as u64, t.n_placed as u64,
            "daemon edge attributes one latency sample per placed task"
        );
    }
}
