//! Sharded two-level service scheduler suite (`sched::service::shard`).
//!
//! Three pins, in rising order of strength:
//!
//! 1. **Single-shard bit-identity.**  `--shards 1` must be the
//!    pre-shard service loop: same decision stream (to_bits on times),
//!    same canonical report JSON bytes (`wire::report_to_json`), same
//!    metrics — across the PR 5 seed matrices, admission policies and
//!    mid-stream cancels.
//! 2. **Cross-shard global invariants.**  For 2–4 shards the *merged*
//!    output must satisfy everything the single loop guarantees
//!    globally: no two tasks of any tenants overlap on one global unit,
//!    per-tenant precedence/arrival feasibility, quota caps, unit
//!    indices inside the platform, and per-shard decision streams that
//!    stay time-monotone inside the merged (operational-order) stream.
//! 3. **Batching parity.**  `admit_batch` — the global layer's
//!    same-window admission batching — is bitwise identical to
//!    admitting one submission at a time, at one shard and at several.

use hetsched::graph::gen;
use hetsched::platform::Platform;
use hetsched::sched::online::OnlinePolicy;
use hetsched::sched::service::{
    run_service, Service, ServiceReport, ShardedService, Submission, TenantPolicy,
};
use hetsched::service_net::wire;
use hetsched::sim::{validate_placements_no_overlap, validate_service};
use hetsched::substrate::rng::Rng;

fn policies(seed: u64) -> [OnlinePolicy; 4] {
    [
        OnlinePolicy::ErLs,
        OnlinePolicy::Eft,
        OnlinePolicy::Greedy,
        OnlinePolicy::Random(seed),
    ]
}

fn admissions() -> [TenantPolicy; 4] {
    [
        TenantPolicy::Fifo,
        TenantPolicy::Quota { cpu_share: 0.5, gpu_share: 1.0 },
        TenantPolicy::WeightedStretch { weight: 0.25 },
        TenantPolicy::WeightedStretch { weight: 4.0 },
    ]
}

/// A contended mixed-policy draw: `n` tenants with tight arrival gaps
/// on whatever platform the caller picked.
fn draw(seed: u64, n: usize, tasks: usize) -> Vec<Submission> {
    let mut rng = Rng::new(0x5A4D_0000 + seed);
    let pol = policies(seed);
    let adm = admissions();
    (0..n)
        .map(|t| {
            let g = gen::hybrid_dag(&mut rng, tasks, 0.15);
            Submission::new(g, t as f64 * 0.75, pol[t % 4].clone())
                .with_admission(adm[t % adm.len()].clone())
        })
        .collect()
}

fn report_bytes(r: &ServiceReport) -> String {
    wire::report_to_json(r).to_string()
}

fn assert_decisions_identical(a: &ServiceReport, b: &ServiceReport, ctx: &str) {
    assert_eq!(a.decisions.len(), b.decisions.len(), "{ctx}: decision counts");
    for (x, y) in a.decisions.iter().zip(&b.decisions) {
        assert_eq!((x.tenant, x.task), (y.tenant, y.task), "{ctx}");
        assert_eq!(x.time.to_bits(), y.time.to_bits(), "{ctx}");
    }
}

// ---------------------------------------------------------------------------
// 1. single-shard bit-identity
// ---------------------------------------------------------------------------

#[test]
fn one_shard_matches_run_service_bitwise() {
    let plat = Platform::hybrid(4, 2);
    for seed in 0..6u64 {
        let subs = draw(seed, 8, 12);
        let reference = run_service(&plat, &subs);
        let mut svc = ShardedService::new(&plat, 1).unwrap();
        for sub in &subs {
            svc.admit(sub.clone()).unwrap();
        }
        svc.run();
        let sharded = svc.report(None);
        let ctx = format!("seed {seed}");
        assert_decisions_identical(&reference, &sharded, &ctx);
        assert_eq!(
            report_bytes(&reference),
            report_bytes(&sharded),
            "{ctx}: 1-shard report JSON diverges from the service loop"
        );
        // every merged decision carries shard 0
        for i in 0..sharded.decisions.len() {
            assert_eq!(svc.decision_shard(i), 0, "{ctx}: decision {i}");
        }
    }
}

#[test]
fn one_shard_matches_the_loop_under_cancels() {
    let plat = Platform::hybrid(4, 2);
    for seed in 0..4u64 {
        let subs = draw(seed, 8, 10);
        let mut reference = Service::empty(&plat);
        let mut svc = ShardedService::new(&plat, 1).unwrap();
        for (t, sub) in subs.iter().enumerate() {
            reference.admit(sub.clone()).unwrap();
            svc.admit(sub.clone()).unwrap();
            if t == 4 {
                let a = reference.cancel(1);
                let b = svc.cancel(1);
                assert_eq!(a.at.to_bits(), b.at.to_bits(), "cancel time");
                assert_eq!(a.dropped_tasks, b.dropped_tasks);
                assert_eq!(a.released_units, b.released_units);
            }
        }
        reference.run();
        svc.run();
        let (ra, rb) = (reference.report(None), svc.report(None));
        let ctx = format!("seed {seed} with cancel");
        assert_decisions_identical(&ra, &rb, &ctx);
        assert_eq!(report_bytes(&ra), report_bytes(&rb), "{ctx}: report bytes");
        // metrics surface delegates too (protects the obs parity pins)
        assert_eq!(
            reference.metrics().report(),
            svc.metrics().report(),
            "{ctx}: metrics diverge"
        );
    }
}

// ---------------------------------------------------------------------------
// 2. cross-shard global invariants
// ---------------------------------------------------------------------------

#[test]
fn merged_schedules_satisfy_global_invariants() {
    // hybrid(8, 4): shard counts 2/3/4 all divide into valid slices
    let plat = Platform::hybrid(8, 4);
    for n_shards in [2usize, 3, 4] {
        for seed in 0..4u64 {
            let subs = draw(10 * n_shards as u64 + seed, 24, 8);
            let mut svc = ShardedService::new(&plat, n_shards).unwrap();
            for sub in &subs {
                svc.admit(sub.clone()).unwrap();
            }
            svc.run();
            let report = svc.report(None);
            let ctx = format!("{n_shards} shards, seed {seed}");

            // (a) per-tenant feasibility + pool-wide no-overlap on the
            // *global* unit numbering (validate_service sees the full
            // platform, so a bad base-offset translation collides here)
            let runs = report.tenant_runs(svc.submissions());
            validate_service(&plat, &runs).unwrap_or_else(|e| panic!("{ctx}: {e}"));

            // (b) translated unit indices stay inside the platform
            for t in &report.tenants {
                for p in &t.schedule.placements {
                    assert!(
                        p.unit < plat.counts[p.ptype],
                        "{ctx}: tenant {} uses unit {} of type {} (only {})",
                        t.tenant, p.unit, p.ptype, plat.counts[p.ptype]
                    );
                }
            }

            // (c) the merged stream is operational-order, but each
            // shard's subsequence must stay time-monotone
            let mut last = vec![f64::NEG_INFINITY; n_shards];
            for (i, d) in report.decisions.iter().enumerate() {
                let s = svc.decision_shard(i);
                assert!(s < n_shards, "{ctx}: decision {i} from shard {s}");
                assert!(
                    d.time >= last[s],
                    "{ctx}: shard {s} stream went backwards at decision {i}"
                );
                last[s] = d.time;
            }

            // (d) every kept task decided exactly once
            let kept: usize = report.tenants.iter().map(|t| t.n_placed).sum();
            assert_eq!(report.decisions.len(), kept, "{ctx}: decisions vs kept tasks");
        }
    }
}

#[test]
fn cancels_keep_the_merged_pool_overlap_free() {
    let plat = Platform::hybrid(8, 4);
    for seed in 0..3u64 {
        let subs = draw(700 + seed, 20, 8);
        let mut svc = ShardedService::new(&plat, 3).unwrap();
        for (t, sub) in subs.iter().enumerate() {
            svc.admit(sub.clone()).unwrap();
            if t == 9 {
                svc.cancel(3);
                svc.cancel(7);
            }
        }
        svc.run();
        let report = svc.report(None);
        // cancelled tenants' schedules are not graph-aligned, so only
        // the pool-wide no-overlap applies to the full placement set
        validate_placements_no_overlap(
            report.tenants.iter().flat_map(|t| &t.schedule.placements),
        )
        .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert!(svc.cancelled_at(3).is_some());
        assert!(svc.cancelled_at(7).is_some());
        let m = svc.metrics();
        assert_eq!(m.counter("svc_cancelled_tenants"), 2, "seed {seed}");
    }
}

#[test]
fn quota_caps_hold_against_the_global_platform() {
    // shares are interpreted against the tenant's shard slice; a slice
    // is never larger than the machine, so the global cap
    // ceil(share · counts[q]) must still hold for every tenant
    let plat = Platform::hybrid(8, 4);
    let (cpu_share, gpu_share) = (0.25, 0.5);
    let mut rng = Rng::new(0x0A07A);
    let mut svc = ShardedService::new(&plat, 2).unwrap();
    for t in 0..16usize {
        let g = gen::hybrid_dag(&mut rng, 10, 0.1);
        let sub = Submission::new(g, t as f64 * 0.5, OnlinePolicy::Eft)
            .with_admission(TenantPolicy::Quota { cpu_share, gpu_share });
        svc.admit(sub).unwrap();
    }
    svc.run();
    let report = svc.report(None);
    let caps = [
        (cpu_share * plat.counts[0] as f64).ceil() as usize,
        (gpu_share * plat.counts[1] as f64).ceil() as usize,
    ];
    for t in &report.tenants {
        for q in 0..2 {
            let mine: Vec<_> = t
                .schedule
                .placements
                .iter()
                .filter(|p| p.ptype == q)
                .collect();
            for p in &mine {
                // distinct units this tenant holds at p.start
                let mut held: Vec<usize> = mine
                    .iter()
                    .filter(|o| o.start <= p.start && p.start < o.finish)
                    .map(|o| o.unit)
                    .collect();
                held.sort_unstable();
                held.dedup();
                assert!(
                    held.len() <= caps[q],
                    "tenant {} holds {} type-{q} units at t={} (cap {})",
                    t.tenant, held.len(), p.start, caps[q]
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// 3. batching parity
// ---------------------------------------------------------------------------

#[test]
fn batched_admission_is_bitwise_identical_to_sequential() {
    let plat = Platform::hybrid(6, 3);
    for n_shards in [1usize, 3] {
        for seed in 0..4u64 {
            // bursts: several same-arrival submissions per window, so
            // real groups form at the global layer
            let mut rng = Rng::new(0xBA7C_0000 + seed);
            let pol = policies(seed);
            let subs: Vec<Submission> = (0..30)
                .map(|t| {
                    let g = gen::hybrid_dag(&mut rng, 6, 0.2);
                    Submission::new(g, (t / 5) as f64 * 2.0, pol[t % 4].clone())
                })
                .collect();

            let mut seq = ShardedService::new(&plat, n_shards).unwrap();
            for sub in &subs {
                seq.admit(sub.clone()).unwrap();
            }
            seq.run();

            let mut bat = ShardedService::new(&plat, n_shards).unwrap();
            let ids = bat.admit_batch(subs.clone()).unwrap();
            assert_eq!(ids, (0..subs.len()).collect::<Vec<_>>());
            bat.run();

            let (ra, rb) = (seq.report(None), bat.report(None));
            let ctx = format!("{n_shards} shards, seed {seed}");
            assert_decisions_identical(&ra, &rb, &ctx);
            assert_eq!(report_bytes(&ra), report_bytes(&rb), "{ctx}: report bytes");
            for (i, d) in ra.decisions.iter().enumerate() {
                assert_eq!(
                    seq.decision_shard(i),
                    bat.decision_shard(i),
                    "{ctx}: decision {i} (tenant {}, task {})",
                    d.tenant,
                    d.task
                );
            }
            for t in 0..seq.n_tenants() {
                assert_eq!(seq.shard_of(t), bat.shard_of(t), "{ctx}: tenant {t}");
            }
        }
    }
}

#[test]
fn admit_batch_rejects_all_or_nothing() {
    let plat = Platform::hybrid(4, 2);
    let mut svc = ShardedService::new(&plat, 2).unwrap();
    let mut rng = Rng::new(0xBAD);
    let good = Submission::new(gen::hybrid_dag(&mut rng, 4, 0.2), 0.0, OnlinePolicy::Greedy);
    let mut bad = good.clone();
    bad.arrival = f64::NAN; // fails validate_submission
    let err = svc.admit_batch(vec![good, bad]);
    assert!(err.is_err(), "invalid member must reject the whole batch");
    assert_eq!(svc.n_tenants(), 0, "nothing admitted on batch rejection");
}
