//! Live coordinator demo: execute a task graph on a real worker-thread
//! pool under each online policy and compare realized makespans with the
//! discrete-event predictions (the deployment mode the paper's §7 aims
//! at, StarPU-style).
//!
//!     cargo run --release --example runtime_serve

use hetsched::coordinator::{run_live, LiveConfig};
use hetsched::platform::Platform;
use hetsched::sched::online::OnlinePolicy;
use hetsched::sim::validate_realized;
use hetsched::workloads::{chameleon, costs::CostModel, forkjoin};

fn main() {
    let plat = Platform::hybrid(6, 2);
    let workloads = vec![
        chameleon::posv(6, &CostModel::hybrid(320), 11),
        forkjoin::forkjoin(40, 3, 1, 11),
    ];

    for g in &workloads {
        println!(
            "== {} ({} tasks) on {} units ({}) ==",
            g.app,
            g.n_tasks(),
            plat.n_units(),
            plat.label()
        );
        let order: Vec<usize> = (0..g.n_tasks()).collect();
        for policy in [OnlinePolicy::ErLs, OnlinePolicy::Eft, OnlinePolicy::Greedy] {
            let name = policy.name();
            // scale virtual time so each run takes well under a second
            let total_work: f64 = (0..g.n_tasks()).map(|j| g.p_cpu(j)).sum();
            let cfg = LiveConfig {
                time_scale: (0.4 / total_work).min(0.002),
                policy,
            };
            let (report, realized) = run_live(g, &plat, &order, &cfg);
            validate_realized(g, &plat, &realized).expect("realized schedule feasible");
            println!(
                "{:>7}: realized {:>9.3} | predicted {:>9.3} | overhead {:>5.1}% | \
                 decision p95 {:>6.1} us | wall {:?}",
                name,
                report.realized_makespan,
                report.predicted_makespan,
                (report.realized_makespan / report.predicted_makespan - 1.0) * 100.0,
                report.decision_latency.p95 * 1e6,
                report.wall,
            );
        }
        println!();
    }
}
