// Wall-clock reads are legitimate here (hetlint no-wallclock-in-core allowlist).
#![allow(clippy::disallowed_methods)]
//! Online campaign driver (Figures 6 and 7 of the paper).
//!
//!     cargo run --release --example online_campaign [-- --scale smoke]
//!
//! Runs ER-LS against the EFT / Greedy / Random baselines on every
//! instance × 2-type config, prints per-app ratio tables, the
//! competitive-ratio-vs-√(m/k) series, and the headline improvements.

use hetsched::analysis::{
    mean_improvement_pct, pairwise_by_app, ratio_by_app, ratio_by_sqrt_mk, records_csv,
    render_summary_table,
};
use hetsched::experiments::{online, CampaignOpts};
use hetsched::substrate::cli::Args;
use hetsched::workloads::Scale;

fn main() {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>());
    let opts = CampaignOpts {
        scale: Scale::parse(&args.string("scale", "default")).unwrap_or(Scale::Default),
        ..Default::default()
    };
    std::fs::create_dir_all("results").ok();

    let t = std::time::Instant::now(); // hetlint: allow(no-wallclock-in-core) -- demo timing readout only; printed, never fed into a schedule
    let records = online::run(&opts);
    eprintln!("online campaign: {} records in {:?}", records.len(), t.elapsed());
    std::fs::write("results/fig6_fig7_records.csv", records_csv(&records)).ok();

    // Fig. 6 left: ratio to LP* per app
    for algo in ["ER-LS", "EFT", "Greedy", "Random"] {
        println!(
            "{}",
            render_summary_table(
                &format!("Fig.6-left makespan/LP* — {algo}"),
                &ratio_by_app(&records, algo)
            )
        );
    }

    // Fig. 6 right: mean competitive ratio vs sqrt(m/k)
    println!("Fig.6-right mean competitive ratio (±stderr) vs sqrt(m/k):");
    for algo in ["ER-LS", "EFT", "Greedy"] {
        let series = ratio_by_sqrt_mk(&records, algo);
        let pts: Vec<String> = series
            .iter()
            .map(|(x, s)| format!("({x:.2}, {:.3}±{:.3})", s.mean, s.stderr))
            .collect();
        println!("  {algo:>7}: {}", pts.join(" "));
    }
    println!();

    // Fig. 7: pairwise
    println!(
        "{}",
        render_summary_table(
            "Fig.7-left Greedy / ER-LS",
            &pairwise_by_app(&records, "Greedy", "ER-LS")
        )
    );
    println!(
        "{}",
        render_summary_table(
            "Fig.7-right EFT / ER-LS",
            &pairwise_by_app(&records, "EFT", "ER-LS")
        )
    );
    println!(
        "ER-LS improves on Greedy by {:.1}% on average (paper: ~16%)",
        mean_improvement_pct(&records, "ER-LS", "Greedy")
    );
    println!(
        "ER-LS loses to EFT by {:.1}% on average (paper: ~10%)",
        -mean_improvement_pct(&records, "ER-LS", "EFT")
    );
}
