// Wall-clock reads are legitimate here (hetlint no-wallclock-in-core allowlist).
#![allow(clippy::disallowed_methods)]
//! Durable service daemon demo: the crash-recovery story end to end,
//! in-process (no sockets — the [`Core`] API is the same one
//! `hetsched serve-service` runs behind TCP).
//!
//! 1. Open a fresh WAL, admit contended tenants, cancel one, drain.
//! 2. "Crash": sever the WAL at an arbitrary record boundary — as if
//!    the daemon was kill -9'd mid-stream.
//! 3. Restart from the severed prefix, re-apply the ops the prefix had
//!    not yet logged, drain again — and verify the decision stream and
//!    the canonical report are **bit-identical** to the uninterrupted
//!    run (replay == rerun).
//!
//!     cargo run --release --example service_daemon

use std::path::Path;

use hetsched::graph::gen;
use hetsched::platform::Platform;
use hetsched::sched::online::OnlinePolicy;
use hetsched::sched::service::Submission;
use hetsched::service_net::server::Core;
use hetsched::service_net::{wal, wire};
use hetsched::substrate::rng::Rng;

enum Op {
    Submit(Submission),
    Cancel(usize),
}

fn ops() -> Vec<Op> {
    let policies = [OnlinePolicy::ErLs, OnlinePolicy::Eft, OnlinePolicy::Greedy];
    let mut rng = Rng::new(4242);
    let mut out = Vec::new();
    for t in 0..8usize {
        let g = gen::hybrid_dag(&mut rng, 120, 0.03);
        out.push(Op::Submit(Submission::new(
            g,
            t as f64 * 10.0,
            policies[t % policies.len()].clone(),
        )));
        if t == 3 {
            out.push(Op::Cancel(1));
        }
    }
    out
}

fn drive(path: &Path, plat: &Platform, ops: &[Op], skip: usize) -> (usize, String) {
    let (mut core, replay) = Core::open(path, plat).expect("wal opens");
    println!(
        "  open {}: {} ops replayed, {} decisions verified{}",
        path.display(),
        replay.ops,
        replay.decisions_logged,
        if replay.decisions_regenerated > 0 {
            format!(", {} regenerated", replay.decisions_regenerated)
        } else {
            String::new()
        }
    );
    for op in ops.iter().skip(skip) {
        match op {
            Op::Submit(s) => {
                core.submit(s.clone()).expect("admitted");
            }
            Op::Cancel(t) => {
                let out = core.cancel(*t).expect("cancelled");
                println!("  cancelled tenant {t} at virtual time {:.2}", out.at);
            }
        }
    }
    let report = core.report().expect("drained");
    (
        core.decisions().len(),
        wire::report_to_json(&report).to_string(),
    )
}

fn main() {
    let dir = std::env::temp_dir().join("hetsched_service_daemon_demo");
    std::fs::create_dir_all(&dir).expect("scratch dir");
    let plat = Platform::hybrid(8, 2);

    println!("uninterrupted run:");
    let full = dir.join("full.wal");
    std::fs::remove_file(&full).ok();
    let (n_ref, ref_report) = drive(&full, &plat, &ops(), 0);
    println!("  drained: {n_ref} decisions");

    // "kill -9" mid-stream: keep the log prefix up to an arbitrary
    // record boundary (here: the middle record)
    let bytes = std::fs::read(&full).expect("read wal");
    let cuts: Vec<usize> = bytes
        .iter()
        .enumerate()
        .filter(|(_, &b)| b == b'\n')
        .map(|(i, _)| i + 1)
        .collect();
    let cut = cuts[cuts.len() / 2];
    let crashed = dir.join("crashed.wal");
    std::fs::write(&crashed, &bytes[..cut]).expect("sever wal");
    println!("\ncrash: wal severed at byte {cut}/{} — restarting:", bytes.len());

    // op records hit the log before they are applied, so the op count
    // in the severed prefix is exactly how many ops to skip on resume
    let scan = wal::recover(&crashed).expect("recover");
    let logged = scan
        .records
        .iter()
        .filter(|r| {
            matches!(
                r,
                wal::WalRecord::Submit { .. } | wal::WalRecord::Cancel { .. } | wal::WalRecord::Drain
            )
        })
        .count();
    let (n_res, res_report) = drive(&crashed, &plat, &ops(), logged);

    assert_eq!(n_ref, n_res);
    assert_eq!(ref_report, res_report, "replay != rerun");
    println!(
        "\nreplay == rerun: {n_res} decisions and the {}-byte canonical report \
         are bit-identical across the crash",
        res_report.len()
    );
    std::fs::remove_dir_all(&dir).ok();
}
