// Wall-clock reads are legitimate here (hetlint no-wallclock-in-core allowlist).
#![allow(clippy::disallowed_methods)]
//! Offline campaign driver (Figures 3, 4 and 5 of the paper).
//!
//!     cargo run --release --example offline_campaign [-- --scale smoke]
//!
//! Runs every benchmark instance × machine configuration ×
//! {HLP-EST, HLP-OLS, HEFT} for 2 resource types and the QHLP versions
//! for 3 types, prints the per-app ratio tables and the headline
//! pairwise improvements, and writes CSVs under results/.

use hetsched::analysis::{
    mean_improvement_pct, pairwise_by_app, ratio_by_app, records_csv, render_summary_table,
};
use hetsched::experiments::{offline, CampaignOpts};
use hetsched::substrate::cli::Args;
use hetsched::workloads::Scale;

fn main() {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>());
    let opts = CampaignOpts {
        scale: Scale::parse(&args.string("scale", "default")).unwrap_or(Scale::Default),
        ..Default::default()
    };
    std::fs::create_dir_all("results").ok();

    // ---- 2 resource types: Fig. 3 + Fig. 4 --------------------------
    let t = std::time::Instant::now(); // hetlint: allow(no-wallclock-in-core) -- demo timing readout only; printed, never fed into a schedule
    let records = offline::run(2, &opts);
    eprintln!("2-type campaign: {} records in {:?}", records.len(), t.elapsed());
    std::fs::write("results/fig3_fig4_records.csv", records_csv(&records)).ok();

    for algo in ["HLP-EST", "HLP-OLS", "HEFT"] {
        println!(
            "{}",
            render_summary_table(
                &format!("Fig.3 makespan/LP* — {algo}"),
                &ratio_by_app(&records, algo)
            )
        );
    }
    println!(
        "{}",
        render_summary_table(
            "Fig.4-left HLP-EST / HLP-OLS",
            &pairwise_by_app(&records, "HLP-EST", "HLP-OLS")
        )
    );
    println!(
        "{}",
        render_summary_table(
            "Fig.4-right HEFT / HLP-OLS",
            &pairwise_by_app(&records, "HEFT", "HLP-OLS")
        )
    );
    println!(
        "HLP-OLS improves on HLP-EST by {:.1}% on average (paper: ~8-10%)",
        mean_improvement_pct(&records, "HLP-OLS", "HLP-EST")
    );
    println!(
        "HLP-OLS improves on HEFT by {:.1}% on average (paper: ~2%)\n",
        mean_improvement_pct(&records, "HLP-OLS", "HEFT")
    );

    // ---- 3 resource types: Fig. 5 -----------------------------------
    let t = std::time::Instant::now(); // hetlint: allow(no-wallclock-in-core) -- demo timing readout only; printed, never fed into a schedule
    let records3 = offline::run(3, &opts);
    eprintln!("3-type campaign: {} records in {:?}", records3.len(), t.elapsed());
    std::fs::write("results/fig5_records.csv", records_csv(&records3)).ok();

    for algo in ["QHLP-EST", "QHLP-OLS", "QHEFT"] {
        println!(
            "{}",
            render_summary_table(
                &format!("Fig.5-left makespan/LP* — {algo}"),
                &ratio_by_app(&records3, algo)
            )
        );
    }
    println!(
        "{}",
        render_summary_table(
            "Fig.5-right QHEFT / QHLP-OLS",
            &pairwise_by_app(&records3, "QHEFT", "QHLP-OLS")
        )
    );
    println!(
        "QHEFT improves on QHLP-OLS by {:.1}% on average (paper: ~5%)",
        mean_improvement_pct(&records3, "QHEFT", "QHLP-OLS")
    );
}
