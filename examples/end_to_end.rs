// Wall-clock reads are legitimate here (hetlint no-wallclock-in-core allowlist).
#![allow(clippy::disallowed_methods)]
//! END-TO-END driver: proves all three layers compose on a real small
//! workload (recorded in EXPERIMENTS.md §End-to-end).
//!
//!     make artifacts && cargo run --release --example end_to_end
//!
//! Pipeline per workload (posv 10×10 tiles and a 100-wide fork-join —
//! 330 and 506 tasks):
//!   1. L3 generates the task DAG and builds the HLP relaxation;
//!   2. the LP is solved by the **AOT JAX/Pallas PDHG artifact through
//!      PJRT** (Layer 1+2; the Rust mirror cross-checks the objective);
//!   3. the rounded allocation is scheduled with HLP-OLS / HLP-EST and
//!      compared against HEFT and the online policies;
//!   4. every schedule is validated (precedences, overlap, durations);
//!   5. the ER-LS decisions are executed *live* on a worker-thread pool
//!      and the realized makespan is compared with the prediction.
//!
//! The headline metric of the paper — makespan / LP* — is printed for
//! every algorithm, and the run fails loudly if any approximation
//! certificate (6·LP* offline, 4√(m/k)·LP* online) is violated.

use hetsched::algos::{run_offline, solve_hlp, Offline};
use hetsched::coordinator::{run_live, LiveConfig};
use hetsched::lp::model::build_hlp;
use hetsched::lp::pdhg::{solve_rust, DriveOpts};
use hetsched::platform::Platform;
use hetsched::runtime::LpBackendKind;
use hetsched::sched::online::{online_by_id, OnlinePolicy};
use hetsched::sim::{validate, validate_realized};
use hetsched::workloads::{chameleon, costs::CostModel, forkjoin};

fn main() {
    let plat = Platform::hybrid(16, 4);
    let sqrt_mk = (plat.m() as f64 / plat.k() as f64).sqrt();
    let workloads = vec![
        chameleon::posv(10, &CostModel::hybrid(320), 2026),
        forkjoin::forkjoin(100, 5, 1, 2026),
    ];

    let mut failures = 0;
    for g in &workloads {
        println!(
            "==== {} : {} tasks, {} arcs, machine {} ====",
            g.app,
            g.n_tasks(),
            g.n_arcs(),
            plat.label()
        );

        // --- Layers 1+2: the AOT PDHG artifact through PJRT ---------
        let t = std::time::Instant::now(); // hetlint: allow(no-wallclock-in-core) -- demo timing readout only; printed, never fed into a schedule
        let hlp = solve_hlp(g, &plat, LpBackendKind::Pjrt, 1e-4);
        println!(
            "LP* = {:.4}  [{}; gap {:.1e}; {} iters; {:?}]",
            hlp.sol.obj,
            hlp.sol.backend,
            hlp.sol.gap,
            hlp.sol.iters,
            t.elapsed()
        );
        assert_eq!(hlp.sol.backend, "pdhg-pjrt", "PJRT path must be exercised");

        // cross-check against the in-tree f64 mirror
        let (lp, _) = build_hlp(g, &plat);
        let mirror = solve_rust(&lp, &DriveOpts { tol: 1e-5, ..Default::default() });
        let dev = (mirror.obj - hlp.sol.obj).abs() / (1.0 + mirror.obj.abs());
        println!(
            "cross-check: rust-pdhg LP* = {:.4} (deviation {:.2e})",
            mirror.obj, dev
        );
        assert!(dev < 5e-3, "backends disagree");

        // --- Layer 3: offline algorithms ----------------------------
        for algo in Offline::ALL {
            let t = std::time::Instant::now(); // hetlint: allow(no-wallclock-in-core) -- demo timing readout only; printed, never fed into a schedule
            let (s, _) = run_offline(algo, g, &plat, Some(&hlp), LpBackendKind::Pjrt, 1e-4);
            if let Err(e) = validate(g, &plat, &s) {
                println!("!! {} produced an INVALID schedule: {e}", algo.name());
                failures += 1;
                continue;
            }
            let ratio = s.makespan / hlp.sol.obj;
            let ok = ratio <= 6.0 * 1.05;
            if !ok {
                failures += 1;
            }
            println!(
                "{:>8}: makespan {:>10.4}  ratio {:>6.3}  [{:>9?}] {}",
                algo.name(),
                s.makespan,
                ratio,
                t.elapsed(),
                if ok { "<= 6 LP* ok" } else { "VIOLATES 6 LP*" }
            );
        }

        // --- Layer 3: online policies -------------------------------
        for policy in [
            OnlinePolicy::ErLs,
            OnlinePolicy::Eft,
            OnlinePolicy::Greedy,
            OnlinePolicy::Random(2026),
        ] {
            let t = std::time::Instant::now(); // hetlint: allow(no-wallclock-in-core) -- demo timing readout only; printed, never fed into a schedule
            let s = online_by_id(g, &plat, &policy);
            validate(g, &plat, &s).expect("online schedule feasible");
            let ratio = s.makespan / hlp.sol.obj;
            let bound_ok = match policy {
                OnlinePolicy::ErLs => ratio <= 4.0 * sqrt_mk + 1e-9,
                _ => true,
            };
            if !bound_ok {
                failures += 1;
            }
            println!(
                "{:>8}: makespan {:>10.4}  ratio {:>6.3}  [{:>9?}] {}",
                policy.name(),
                s.makespan,
                ratio,
                t.elapsed(),
                match policy {
                    OnlinePolicy::ErLs if bound_ok => "<= 4*sqrt(m/k) LP* ok",
                    OnlinePolicy::ErLs => "VIOLATES competitive bound",
                    _ => "",
                }
            );
        }

        // --- live execution on the coordinator's worker pool --------
        let small = Platform::hybrid(6, 2); // one OS thread per unit
        let order: Vec<usize> = (0..g.n_tasks()).collect();
        let total_work: f64 = (0..g.n_tasks()).map(|j| g.p_cpu(j)).sum();
        // scale virtual time so the mean task sleeps ~1 ms (well above
        // OS timer granularity) while the whole run stays sub-second
        let mean_task = total_work / g.n_tasks() as f64;
        let cfg = LiveConfig {
            time_scale: 0.004 / mean_task,
            policy: OnlinePolicy::ErLs,
        };
        let (report, realized) = run_live(g, &small, &order, &cfg);
        validate_realized(g, &small, &realized).expect("realized schedule feasible");
        println!(
            "live ER-LS on {} worker threads: realized {:.3} vs predicted {:.3} \
             (+{:.1}%), decision p95 {:.1} us, wall {:?}\n",
            small.n_units(),
            report.realized_makespan,
            report.predicted_makespan,
            (report.realized_makespan / report.predicted_makespan - 1.0) * 100.0,
            report.decision_latency.p95 * 1e6,
            report.wall
        );
    }

    if failures > 0 {
        eprintln!("END-TO-END: {failures} certificate violations");
        std::process::exit(1);
    }
    println!("END-TO-END: all layers compose; all certificates hold.");
}
