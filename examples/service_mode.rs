// Wall-clock reads are legitimate here (hetlint no-wallclock-in-core allowlist).
#![allow(clippy::disallowed_methods)]
//! Multi-tenant streaming service demo: 50 applications of 1000 tasks
//! each arrive over virtual time into one shared 32-CPU + 8-GPU pool and
//! flow through the irrevocable online policies (ER-LS / EFT / Greedy),
//! exactly the shared-cluster regime the paper's on-line model (§4.2)
//! targets for deployment (§7) — then the same contended workload is
//! replayed under each admission policy (FIFO / Quota / WeightedStretch)
//! and the fairness aggregates are compared side by side.
//!
//!     cargo run --release --example service_mode

use std::time::Instant;

use hetsched::graph::gen;
use hetsched::graph::TaskGraph;
use hetsched::platform::Platform;
use hetsched::sched::online::{online_by_id, OnlinePolicy};
use hetsched::sched::service::{run_service, ServiceReport, Submission, TenantPolicy};
use hetsched::sim::validate_service;
use hetsched::substrate::rng::Rng;

fn make_graphs() -> Vec<(TaskGraph, f64, OnlinePolicy)> {
    let policies = [OnlinePolicy::ErLs, OnlinePolicy::Eft, OnlinePolicy::Greedy];
    let mut rng = Rng::new(2027);
    // 50 tenants × 1000 tasks, arrivals staggered so the pool stays
    // contended but the queue keeps draining
    (0..50)
        .map(|t| {
            let g = gen::hybrid_dag(&mut rng, 1000, 0.004);
            (g, t as f64 * 40.0, policies[t % policies.len()].clone())
        })
        .collect()
}

fn subs_with(base: &[(TaskGraph, f64, OnlinePolicy)], admission: &TenantPolicy) -> Vec<Submission> {
    base.iter()
        .map(|(g, arrival, policy)| {
            Submission::new(g.clone(), *arrival, policy.clone())
                .with_admission(admission.clone())
        })
        .collect()
}

fn main() {
    let plat = Platform::hybrid(32, 8);
    let base = make_graphs();
    let total_tasks: usize = base.iter().map(|(g, _, _)| g.n_tasks()).sum();
    println!(
        "service: {} tenants, {} tasks total, pool {} ({} units)",
        base.len(),
        total_tasks,
        plat.label(),
        plat.n_units()
    );

    // ---- FIFO (the golden baseline) --------------------------------
    let subs = subs_with(&base, &TenantPolicy::Fifo);
    let t0 = Instant::now(); // hetlint: allow(no-wallclock-in-core) -- demo timing readout only; printed, never fed into a schedule
    let fifo = run_service(&plat, &subs);
    let wall = t0.elapsed();
    assert_eq!(fifo.total_tasks, 50 * 1000);
    assert_eq!(fifo.decisions.len(), 50 * 1000);

    // pool-wide feasibility: per-tenant precedences + no cross-tenant
    // overlap on any unit
    validate_service(&plat, &fifo.tenant_runs(&subs)).expect("service schedule feasible");

    // golden parity: a lone tenant places exactly like sched::online
    let lone = vec![Submission::new(
        base[0].0.clone(),
        0.0,
        base[0].2.clone(),
    )];
    let lone_report = run_service(&plat, &lone);
    let expect = online_by_id(&base[0].0, &plat, &base[0].2);
    assert_eq!(
        lone_report.tenants[0].schedule.placements, expect.placements,
        "single-tenant service must match the online engine"
    );

    println!(
        "scheduled {} decisions in {:?} ({:.0} decisions/s)\n",
        fifo.decisions.len(),
        wall,
        fifo.decisions.len() as f64 / wall.as_secs_f64()
    );
    println!(
        "{:>6} {:>8} {:>9} {:>10} {:>10} {:>9} {:>8} {:>12}",
        "tenant", "policy", "arrival", "complete", "flow", "ideal", "stretch", "p95 dec (us)"
    );
    for (t, s) in fifo.tenants.iter().zip(&subs).take(10) {
        println!(
            "{:>6} {:>8} {:>9.1} {:>10.1} {:>10.1} {:>9.1} {:>8.2} {:>12.1}",
            t.tenant,
            s.policy.name(),
            t.arrival,
            t.completion,
            t.flow_time,
            t.ideal_makespan,
            t.stretch,
            t.decision_latency.p95 * 1e6
        );
    }
    println!("   ... ({} more tenants)\n", fifo.tenants.len() - 10);

    // ---- the same contended workload under each admission policy ---
    let quota = TenantPolicy::Quota { cpu_share: 0.25, gpu_share: 0.25 };
    let ws = TenantPolicy::WeightedStretch { weight: 1.0 };
    let rows: Vec<(&str, ServiceReport)> = vec![
        ("FIFO", fifo),
        ("Quota .25/.25", {
            let subs = subs_with(&base, &quota);
            let r = run_service(&plat, &subs);
            validate_service(&plat, &r.tenant_runs(&subs)).expect("quota schedule feasible");
            r
        }),
        ("WStretch w=1", {
            let subs = subs_with(&base, &ws);
            let r = run_service(&plat, &subs);
            validate_service(&plat, &r.tenant_runs(&subs)).expect("ws schedule feasible");
            r
        }),
    ];

    println!(
        "{:>14} {:>9} {:>11} {:>10} {:>9} {:>7} {:>9} {:>9}",
        "admission", "horizon", "mean str", "max str", "p99 str", "Jain", "util CPU", "util GPU"
    );
    for (name, r) in &rows {
        println!(
            "{:>14} {:>9.1} {:>11.2} {:>10.2} {:>9.2} {:>7.3} {:>8.0}% {:>8.0}%",
            name,
            r.horizon,
            r.mean_stretch,
            r.max_stretch,
            r.stretch_p99,
            r.jain_index,
            r.utilization[0] * 100.0,
            r.utilization[1] * 100.0
        );
    }

    // the acceptance property the test suite and ci.sh --perf pin:
    // weighted stretch strictly reduces the stretch tail vs FIFO
    let (fifo_max, ws_max) = (rows[0].1.max_stretch, rows[2].1.max_stretch);
    assert!(
        ws_max < fifo_max,
        "WeightedStretch must strictly reduce max stretch ({ws_max} vs {fifo_max})"
    );
    println!(
        "\nWeightedStretch cuts max stretch {:.2} -> {:.2} ({:.0}% of FIFO)",
        fifo_max,
        ws_max,
        ws_max / fifo_max * 100.0
    );
}
