//! Multi-tenant streaming service demo: 50 applications of 1000 tasks
//! each arrive over virtual time into one shared 32-CPU + 8-GPU pool and
//! flow through the irrevocable online policies (ER-LS / EFT / Greedy),
//! exactly the shared-cluster regime the paper's on-line model (§4.2)
//! targets for deployment (§7).
//!
//!     cargo run --release --example service_mode

use std::time::Instant;

use hetsched::graph::gen;
use hetsched::platform::Platform;
use hetsched::sched::online::{online_by_id, OnlinePolicy};
use hetsched::sched::service::{run_service, Submission};
use hetsched::sim::validate_service;
use hetsched::substrate::rng::Rng;

fn main() {
    let plat = Platform::hybrid(32, 8);
    let policies = [OnlinePolicy::ErLs, OnlinePolicy::Eft, OnlinePolicy::Greedy];
    let mut rng = Rng::new(2027);

    // 50 tenants × 1000 tasks, arrivals staggered so the pool stays
    // contended but the queue keeps draining
    let subs: Vec<Submission> = (0..50)
        .map(|t| {
            let g = gen::hybrid_dag(&mut rng, 1000, 0.004);
            let arrival = t as f64 * 40.0;
            Submission::new(g, arrival, policies[t % policies.len()].clone())
        })
        .collect();
    let total_tasks: usize = subs.iter().map(|s| s.graph.n_tasks()).sum();
    println!(
        "service: {} tenants, {} tasks total, pool {} ({} units)",
        subs.len(),
        total_tasks,
        plat.label(),
        plat.n_units()
    );

    let t0 = Instant::now();
    let report = run_service(&plat, &subs);
    let wall = t0.elapsed();
    assert_eq!(report.total_tasks, 50 * 1000);
    assert_eq!(report.decisions.len(), 50 * 1000);

    // pool-wide feasibility: per-tenant precedences + no cross-tenant
    // overlap on any unit
    validate_service(&plat, &report.tenant_runs(&subs)).expect("service schedule feasible");

    // golden parity: a lone tenant places exactly like sched::online
    let lone = vec![Submission::new(
        subs[0].graph.clone(),
        0.0,
        subs[0].policy.clone(),
    )];
    let lone_report = run_service(&plat, &lone);
    let expect = online_by_id(&subs[0].graph, &plat, &subs[0].policy);
    assert_eq!(
        lone_report.tenants[0].schedule.placements, expect.placements,
        "single-tenant service must match the online engine"
    );

    println!(
        "scheduled {} decisions in {:?} ({:.0} decisions/s)\n",
        report.decisions.len(),
        wall,
        report.decisions.len() as f64 / wall.as_secs_f64()
    );
    println!(
        "{:>6} {:>8} {:>9} {:>10} {:>10} {:>9} {:>8} {:>12}",
        "tenant", "policy", "arrival", "complete", "flow", "ideal", "stretch", "p95 dec (us)"
    );
    for (t, s) in report.tenants.iter().zip(&subs).take(10) {
        println!(
            "{:>6} {:>8} {:>9.1} {:>10.1} {:>10.1} {:>9.1} {:>8.2} {:>12.1}",
            t.tenant,
            s.policy.name(),
            t.arrival,
            t.completion,
            t.flow_time,
            t.ideal_makespan,
            t.stretch,
            t.decision_latency.p95 * 1e6
        );
    }
    println!("   ... ({} more tenants)\n", report.tenants.len() - 10);
    println!(
        "horizon {:.1} | mean stretch {:.2} | max stretch {:.2} | utilization CPU {:.0}% GPU {:.0}%",
        report.horizon,
        report.mean_stretch,
        report.max_stretch,
        report.utilization[0] * 100.0,
        report.utilization[1] * 100.0
    );
}
