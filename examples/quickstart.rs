//! Quickstart: the library in ~40 lines.
//!
//! Build a tiled-Cholesky task graph, solve the paper's HLP allocation
//! LP (JAX/Pallas PDHG via PJRT when `make artifacts` has run, Rust
//! mirror otherwise), and compare HLP-OLS / HLP-EST / HEFT and the
//! online ER-LS on a 16-CPU + 4-GPU machine.
//!
//!     cargo run --release --example quickstart

use hetsched::algos::{run_offline, solve_hlp, Offline};
use hetsched::platform::Platform;
use hetsched::runtime::LpBackendKind;
use hetsched::sched::online::{online_by_id, OnlinePolicy};
use hetsched::sim::validate;
use hetsched::workloads::{chameleon, costs::CostModel};

fn main() {
    // 1. the application: potrf (tiled Cholesky), 10x10 tiles of 320
    let g = chameleon::potrf(10, &CostModel::hybrid(320), 42);
    println!("app {}: {} tasks, {} arcs", g.app, g.n_tasks(), g.n_arcs());

    // 2. the machine: m = 16 CPUs, k = 4 GPUs
    let plat = Platform::hybrid(16, 4);

    // 3. allocation phase: solve + round the HLP relaxation
    let hlp = solve_hlp(&g, &plat, LpBackendKind::Auto, 1e-4);
    println!(
        "LP* = {:.4} (backend {}, {} iters)",
        hlp.sol.obj, hlp.sol.backend, hlp.sol.iters
    );

    // 4. scheduling phase: the paper's three offline algorithms
    for algo in Offline::ALL {
        let (s, _) = run_offline(algo, &g, &plat, Some(&hlp), LpBackendKind::Auto, 1e-4);
        validate(&g, &plat, &s).expect("schedule must be feasible");
        println!(
            "{:>8}: makespan {:.4}  (ratio to LP* {:.3})",
            algo.name(),
            s.makespan,
            s.makespan / hlp.sol.obj
        );
    }

    // 5. the online algorithm (tasks revealed one by one, irrevocably)
    let s = online_by_id(&g, &plat, &OnlinePolicy::ErLs);
    validate(&g, &plat, &s).expect("schedule must be feasible");
    println!(
        "{:>8}: makespan {:.4}  (ratio to LP* {:.3})",
        "ER-LS",
        s.makespan,
        s.makespan / hlp.sol.obj
    );
}
