// Wall-clock reads are legitimate here (hetlint no-wallclock-in-core allowlist).
#![allow(clippy::disallowed_methods)]
//! LP-backend performance harness (EXPERIMENTS.md §Perf): times the full
//! HLP solve (build + Ruiz + warm start + PDHG drive) on campaign-sized
//! instances for the PJRT artifact backend vs the Rust mirror.
//!
//!     cargo run --release --example lp_perf

use hetsched::algos::solve_hlp;
use hetsched::platform::Platform;
use hetsched::runtime::LpBackendKind;
use hetsched::workloads::{chameleon, costs::CostModel, forkjoin};
use std::time::Instant;

fn main() {
    let cases: Vec<(&str, hetsched::graph::TaskGraph, Platform)> = vec![
        ("potri-nb5 (105t)", chameleon::potri(5, &CostModel::hybrid(320), 7), Platform::hybrid(4, 2)),
        ("potri-nb10 (660t)", chameleon::potri(10, &CostModel::hybrid(320), 7), Platform::hybrid(16, 4)),
        ("forkjoin-500x5 (2506t)", forkjoin::forkjoin(500, 5, 1, 2026), Platform::hybrid(16, 4)),
        ("potri-nb20 (4620t)", chameleon::potri(20, &CostModel::hybrid(320), 7), Platform::hybrid(64, 8)),
    ];
    for (name, g, plat) in cases {
        println!("{name}:");
        for backend in [LpBackendKind::RustPdhg, LpBackendKind::Pjrt] {
            let t = Instant::now(); // hetlint: allow(no-wallclock-in-core) -- demo timing readout only; printed, never fed into a schedule
            let sol = solve_hlp(&g, &plat, backend, 1e-4);
            let dt = t.elapsed();
            println!(
                "  {:>10}: obj {:.4} gap {:.2e} iters {:>7} in {:>12?}  ({:.0} iters/s)",
                sol.sol.backend, sol.sol.obj, sol.sol.gap, sol.sol.iters, dt,
                sol.sol.iters as f64 / dt.as_secs_f64()
            );
        }
    }
}
