// Wall-clock reads are legitimate here (hetlint no-wallclock-in-core allowlist).
#![allow(clippy::disallowed_methods)]
//! Probe PJRT vs Rust convergence on campaign LPs (dev/perf tool).
use hetsched::algos::solve_hlp_capped;
use hetsched::platform::Platform;
use hetsched::runtime::LpBackendKind;
use hetsched::workloads::{chameleon, costs::CostModel};
use std::time::Instant;

fn main() {
    let g = chameleon::posv(10, &CostModel::hybrid(320), 3);
    let plat = Platform::hybrid(16, 4);
    for backend in [LpBackendKind::RustPdhg, LpBackendKind::Pjrt] {
        let t = Instant::now(); // hetlint: allow(no-wallclock-in-core) -- demo timing readout only; printed, never fed into a schedule
        let sol = solve_hlp_capped(&g, &plat, backend, 1e-4, 400_000);
        println!("{}: obj {:.5} gap {:.2e} iters {} in {:?}", sol.sol.backend, sol.sol.obj, sol.sol.gap, sol.sol.iters, t.elapsed());
    }
}
