//! hetlint — repo-specific static analysis for hetsched.
//!
//! The whole verification story of this repo rests on two conventions
//! that no compiler checks: **determinism** (golden parity pins
//! engine == reference placements bit-for-bit, FIFO service runs are
//! bit-identical to the frozen pre-policy path, coordinator replay ==
//! engine prediction) and **irrevocability** (online/service decisions
//! are taken once, through one engine, and never silently depend on
//! ambient state like wall-clock time).  hetlint turns those
//! conventions into machine-checked rules over a hand-rolled Rust
//! token stream (strings, char literals and comments handled
//! correctly; `#[cfg(test)]` items skipped).
//!
//! # Rules and the invariants they protect
//!
//! | rule                     | protects                                           |
//! |--------------------------|----------------------------------------------------|
//! | `float-total-order`      | NaN-robust ordering everywhere: `partial_cmp` on   |
//! |                          | floats panics or lies on NaN; `total_cmp` is the   |
//! |                          | total order golden parity assumes.                 |
//! | `no-raw-float-eq`        | Tie handling in the decision core: raw `==`/`!=`   |
//! |                          | against float literals is almost never what a      |
//! |                          | decision comparator means — event-time ties go     |
//! |                          | through exact `engine::Tick` integer compares;     |
//! |                          | deliberate exact structural comparisons must say   |
//! |                          | so in a justified suppression.                     |
//! | `no-unordered-iteration` | Replay == rerun: `HashMap`/`HashSet` iteration     |
//! |                          | order is randomized per process, so any iteration  |
//! |                          | in `sched/`, `lp/`, `sim/` can leak               |
//! |                          | nondeterminism into placements or reports; use     |
//! |                          | `BTreeMap`/`BTreeSet` or sort first.               |
//! | `no-wallclock-in-core`   | Irrevocable decisions are functions of virtual     |
//! |                          | time only: `Instant::now`/`SystemTime` in `sched/` |
//! |                          | or `lp/` could feed real time into a placement.    |
//! |                          | Only `coordinator/`, `substrate/bench.rs`,         |
//! |                          | `main.rs` and `rust/benches/` may read real time.  |
//! | `no-panic-in-hot-path`   | A panic mid-schedule abandons irrevocable          |
//! |                          | decisions already taken: `unwrap`/`expect` in the  |
//! |                          | engine decision loops must carry a justified       |
//! |                          | invariant, and the per-file indexing budget        |
//! |                          | ratchets the `x[i]` panic surface.                 |
//! | `forbid-unsafe`          | The determinism argument is memory-safety-deep:    |
//! |                          | no `unsafe` anywhere in the tree.                  |
//! | `no-float-time-in-core`  | The tick clock stays integer: in the hot-path      |
//! |                          | scheduler files, a comparison operator touching a  |
//! |                          | float literal, a reintroduced `TIE_BAND`/          |
//! |                          | `band_eq`/`band_ne`, or an epsilon-band literal    |
//! |                          | (0 < x <= 1e-6) would silently revive the float    |
//! |                          | tie band the `engine::Tick` migration removed.     |
//!
//! # Suppressions
//!
//! A finding is suppressible only inline:
//!
//! ```text
//! // hetlint: allow(<rule>) -- <mandatory justification>
//! ```
//!
//! on the offending line (trailing) or alone on the line directly above
//! it.  Empty justifications, unknown rule names and suppressions that
//! match no finding are themselves findings (`bad-suppression`,
//! `unused-suppression`) and cannot be suppressed.
//!
//! # Output
//!
//! Human-readable findings on stderr plus `ANALYSIS.json` (rule, file,
//! line, snippet, suppressions) at the repo root.  Exit code 1 iff any
//! unsuppressed finding exists.  Run via `cargo run -p hetlint
//! --release` (the `== hetlint ==` stage of `ci.sh`).

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

// ---------------------------------------------------------------------------
// Tokens
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Kind {
    Ident,
    Int,
    Float,
    Punct,
    Str,
    Char,
    Lifetime,
}

#[derive(Clone, Debug)]
struct Token {
    kind: Kind,
    text: String,
    line: usize,
}

struct Lexed {
    tokens: Vec<Token>,
    /// `//` line comments as (line, full text including the slashes).
    comments: Vec<(usize, String)>,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Longest-match multi-char operators; everything else is a single char.
const PUNCTS3: &[&str] = &["<<=", ">>=", "..=", "..."];
const PUNCTS2: &[&str] = &[
    "::", "->", "=>", "==", "!=", "<=", ">=", "&&", "||", "..", "<<", ">>", "+=", "-=", "*=",
    "/=", "%=", "^=", "&=", "|=",
];

/// Hand-rolled Rust lexer: good enough to distinguish code from
/// strings/chars/comments and to classify float literals; it does not
/// try to be a full grammar.
fn lex(src: &str) -> Lexed {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut i = 0usize;
    let mut line = 1usize;
    let mut tokens: Vec<Token> = Vec::new();
    let mut comments: Vec<(usize, String)> = Vec::new();

    let push = |tokens: &mut Vec<Token>, kind: Kind, text: String, line: usize| {
        tokens.push(Token { kind, text, line });
    };

    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            let start = i;
            while i < n && cs[i] != '\n' {
                i += 1;
            }
            comments.push((line, cs[start..i].iter().collect()));
            continue;
        }
        // block comment (Rust block comments nest)
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if cs[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if cs[i] == '/' && i + 1 < n && cs[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if cs[i] == '*' && i + 1 < n && cs[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        // raw strings r"..." / r#"..."#, byte strings b"...", raw idents r#x
        if c == 'r' || c == 'b' {
            let mut j = i + 1;
            let mut has_r = c == 'r';
            if c == 'b' && j < n && cs[j] == 'r' {
                has_r = true;
                j += 1;
            }
            let mut hashes = 0usize;
            while has_r && j < n && cs[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && cs[j] == '"' {
                let start_line = line;
                if has_r {
                    // raw: ends at '"' followed by `hashes` '#'s
                    i = j + 1;
                    'raw: while i < n {
                        if cs[i] == '\n' {
                            line += 1;
                            i += 1;
                            continue;
                        }
                        if cs[i] == '"' {
                            let mut k = 0usize;
                            while k < hashes && i + 1 + k < n && cs[i + 1 + k] == '#' {
                                k += 1;
                            }
                            if k == hashes {
                                i += 1 + hashes;
                                break 'raw;
                            }
                        }
                        i += 1;
                    }
                } else {
                    i = scan_string(&cs, j, &mut line);
                }
                push(&mut tokens, Kind::Str, String::new(), start_line);
                continue;
            }
            if has_r && hashes == 1 && j < n && is_ident_start(cs[j]) {
                // raw identifier r#ident
                let start = j;
                i = j;
                while i < n && is_ident_continue(cs[i]) {
                    i += 1;
                }
                push(&mut tokens, Kind::Ident, cs[start..i].iter().collect(), line);
                continue;
            }
            // otherwise: plain identifier starting with r/b — fall through
        }
        if c == '"' {
            let start_line = line;
            i = scan_string(&cs, i, &mut line);
            push(&mut tokens, Kind::Str, String::new(), start_line);
            continue;
        }
        if c == '\'' {
            // lifetime ('a) vs char literal ('a', '\n', '\'')
            let j = i + 1;
            let is_lifetime = j < n
                && is_ident_start(cs[j])
                && !(j + 1 < n && cs[j + 1] == '\'');
            if is_lifetime {
                let start = j;
                i = j;
                while i < n && is_ident_continue(cs[i]) {
                    i += 1;
                }
                push(&mut tokens, Kind::Lifetime, cs[start..i].iter().collect(), line);
                continue;
            }
            i += 1; // opening quote
            if i < n && cs[i] == '\\' {
                i += 2; // backslash + escaped char (covers \', \\; \x.. tail below)
            }
            while i < n && cs[i] != '\'' {
                if cs[i] == '\n' {
                    line += 1;
                }
                i += 1;
            }
            i += 1; // closing quote
            push(&mut tokens, Kind::Char, String::new(), line);
            continue;
        }
        if c.is_ascii_digit() {
            let start = i;
            let mut float = false;
            if c == '0' && i + 1 < n && matches!(cs[i + 1], 'x' | 'X' | 'b' | 'B' | 'o' | 'O') {
                i += 2;
                while i < n && (cs[i].is_ascii_alphanumeric() || cs[i] == '_') {
                    i += 1;
                }
            } else {
                while i < n && (cs[i].is_ascii_digit() || cs[i] == '_') {
                    i += 1;
                }
                if i < n && cs[i] == '.' {
                    let nxt = cs.get(i + 1).copied();
                    match nxt {
                        Some(d) if d.is_ascii_digit() => {
                            float = true;
                            i += 1;
                            while i < n && (cs[i].is_ascii_digit() || cs[i] == '_') {
                                i += 1;
                            }
                        }
                        Some('.') => {}                            // range: 1..x
                        Some(d) if is_ident_start(d) => {}         // method: 1.max(..)
                        _ => {
                            float = true; // trailing dot: `1.`
                            i += 1;
                        }
                    }
                }
                if i < n && matches!(cs[i], 'e' | 'E') {
                    let mut j = i + 1;
                    if j < n && matches!(cs[j], '+' | '-') {
                        j += 1;
                    }
                    if j < n && cs[j].is_ascii_digit() {
                        float = true;
                        i = j;
                        while i < n && (cs[i].is_ascii_digit() || cs[i] == '_') {
                            i += 1;
                        }
                    }
                }
                // type suffix (f64 / u32 / ...)
                let sfx_start = i;
                while i < n && is_ident_continue(cs[i]) {
                    i += 1;
                }
                let sfx: String = cs[sfx_start..i].iter().collect();
                if sfx == "f32" || sfx == "f64" {
                    float = true;
                }
            }
            let kind = if float { Kind::Float } else { Kind::Int };
            push(&mut tokens, kind, cs[start..i].iter().collect(), line);
            continue;
        }
        if is_ident_start(c) {
            let start = i;
            while i < n && is_ident_continue(cs[i]) {
                i += 1;
            }
            push(&mut tokens, Kind::Ident, cs[start..i].iter().collect(), line);
            continue;
        }
        // punctuation: longest match
        let mut matched = false;
        if i + 2 < n {
            let three: String = cs[i..i + 3].iter().collect();
            if PUNCTS3.contains(&three.as_str()) {
                push(&mut tokens, Kind::Punct, three, line);
                i += 3;
                matched = true;
            }
        }
        if !matched && i + 1 < n {
            let two: String = cs[i..i + 2].iter().collect();
            if PUNCTS2.contains(&two.as_str()) {
                push(&mut tokens, Kind::Punct, two, line);
                i += 2;
                matched = true;
            }
        }
        if !matched {
            push(&mut tokens, Kind::Punct, c.to_string(), line);
            i += 1;
        }
    }
    Lexed { tokens, comments }
}

/// Scan a `"..."` string with escapes; `i` points at the opening quote.
/// Returns the index one past the closing quote, updating `line`.
fn scan_string(cs: &[char], mut i: usize, line: &mut usize) -> usize {
    let n = cs.len();
    i += 1;
    while i < n {
        match cs[i] {
            '\\' => {
                if i + 1 < n && cs[i + 1] == '\n' {
                    *line += 1;
                }
                i += 2;
            }
            '"' => {
                i += 1;
                return i;
            }
            '\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

// ---------------------------------------------------------------------------
// #[cfg(test)] masking
// ---------------------------------------------------------------------------

/// True if tokens at `i` start a `#[cfg(test)]`-style or `#[test]`
/// attribute (any `cfg(...)` attribute mentioning `test`, e.g.
/// `#[cfg(all(test, feature = "x"))]`).
fn is_test_attr(ts: &[Token], i: usize) -> bool {
    if ts[i].text != "#" || ts.get(i + 1).map(|t| t.text.as_str()) != Some("[") {
        return false;
    }
    if ts.get(i + 2).map(|t| t.text.as_str()) == Some("test")
        && ts.get(i + 3).map(|t| t.text.as_str()) == Some("]")
    {
        return true;
    }
    let mut j = i + 2;
    let mut depth = 1usize;
    let mut saw_cfg = false;
    let mut saw_test = false;
    while j < ts.len() && depth > 0 {
        match ts[j].text.as_str() {
            "[" => depth += 1,
            "]" => depth -= 1,
            "cfg" => saw_cfg = true,
            "test" => saw_test = true,
            _ => {}
        }
        j += 1;
    }
    saw_cfg && saw_test
}

/// Mask of tokens inside `#[cfg(test)]`/`#[test]`-annotated items
/// (attribute through the end of the item's `{...}` body or `;`).
/// Test-only code cannot break schedule determinism, so the rules skip
/// it — except that `forbid-unsafe` is additionally enforced by the
/// crate-level `#![forbid(unsafe_code)]`.
fn test_mask(ts: &[Token]) -> Vec<bool> {
    let mut mask = vec![false; ts.len()];
    let mut i = 0usize;
    while i < ts.len() {
        if !is_test_attr(ts, i) {
            i += 1;
            continue;
        }
        // consume the attribute itself
        let mut j = i + 2;
        let mut depth = 1usize;
        while j < ts.len() && depth > 0 {
            match ts[j].text.as_str() {
                "[" => depth += 1,
                "]" => depth -= 1,
                _ => {}
            }
            j += 1;
        }
        // consume the annotated item: up to a top-level `;` or the
        // matching `}` of its first top-level `{`
        let mut k = j;
        let mut pd = 0i64;
        while k < ts.len() {
            let t = ts[k].text.as_str();
            match t {
                "(" | "[" => pd += 1,
                ")" | "]" => pd -= 1,
                ";" if pd == 0 => {
                    k += 1;
                    break;
                }
                "{" if pd == 0 => {
                    let mut bd = 1usize;
                    k += 1;
                    while k < ts.len() && bd > 0 {
                        match ts[k].text.as_str() {
                            "{" => bd += 1,
                            "}" => bd -= 1,
                            _ => {}
                        }
                        k += 1;
                    }
                    break;
                }
                _ => {}
            }
            k += 1;
        }
        for m in mask.iter_mut().take(k).skip(i) {
            *m = true;
        }
        i = k;
    }
    mask
}

// ---------------------------------------------------------------------------
// Rules
// ---------------------------------------------------------------------------

const R1: &str = "float-total-order";
const R2: &str = "no-raw-float-eq";
const R3: &str = "no-unordered-iteration";
const R4: &str = "no-wallclock-in-core";
const R5: &str = "no-panic-in-hot-path";
const R6: &str = "forbid-unsafe";
const R7: &str = "no-float-time-in-core";
const BAD_SUPPRESSION: &str = "bad-suppression";
const UNUSED_SUPPRESSION: &str = "unused-suppression";

/// The rules an inline suppression may name.
const RULES: &[&str] = &[R1, R2, R3, R4, R5, R6, R7];

/// Files whose decision loops are the engine hot path: `unwrap`/
/// `expect` there needs a justified invariant, and the indexing budget
/// below ratchets the panic surface.
const HOT_PATHS: &[&str] = &[
    "rust/src/sched/engine.rs",
    "rust/src/sched/est.rs",
    "rust/src/sched/heft.rs",
    "rust/src/sched/list.rs",
    "rust/src/sched/online.rs",
];

/// Indexing-expression budget per hot-path file (count of `expr[idx]`
/// sites outside `#[cfg(test)]`).  Exceeding the budget is a
/// `no-panic-in-hot-path` finding: either remove index expressions or
/// consciously raise the budget here (the diff makes the decision
/// reviewable).  Lower opportunistically; never raise silently.
const INDEX_BUDGET: &[(&str, usize)] = &[
    // engine grew the UnitTree range-descent (`min_over`/
    // `first_at_most_over`) and the Tick plumbing in this pass; the
    // others moved by at most one site.  Re-verified after the
    // saturating-tick hardening pass: every file sits exactly at its
    // ceiling, so no re-ratchet was possible.
    ("rust/src/sched/engine.rs", 47),
    ("rust/src/sched/est.rs", 15),
    ("rust/src/sched/heft.rs", 8),
    ("rust/src/sched/list.rs", 18),
    ("rust/src/sched/online.rs", 16),
];

fn in_core(rel: &str) -> bool {
    rel.starts_with("rust/src/sched/") || rel.starts_with("rust/src/lp/")
}

fn in_det_modules(rel: &str) -> bool {
    in_core(rel) || rel.starts_with("rust/src/sim/")
}

fn wallclock_allowed(rel: &str) -> bool {
    rel.starts_with("rust/src/coordinator/")
        // daemon edge: uptime/ops accounting only, never scheduling input
        || rel.starts_with("rust/src/service_net/")
        || rel == "rust/src/substrate/bench.rs"
        || rel == "rust/src/main.rs"
        || rel.starts_with("rust/benches/")
}

#[derive(Clone, Debug)]
struct Finding {
    rule: String,
    file: String,
    line: usize,
    msg: String,
    snippet: String,
}

#[derive(Clone, Debug)]
struct Suppressed {
    rule: String,
    file: String,
    line: usize,
    justification: String,
}

struct Suppression {
    /// Line of the comment itself.
    line: usize,
    /// Line the suppression applies to.
    target: usize,
    rules: Vec<String>,
    justification: String,
    used: bool,
}

/// Parse `// hetlint: allow(rule[, rule]) -- justification` comments.
/// Malformed directives become `bad-suppression` findings.
fn parse_suppressions(
    rel: &str,
    lexed: &Lexed,
    lines: &[&str],
    findings: &mut Vec<Finding>,
) -> Vec<Suppression> {
    let mut sups = Vec::new();
    for (cline, text) in &lexed.comments {
        let Some(pos) = text.find("hetlint:") else {
            continue;
        };
        let line = *cline;
        let snippet = snippet_at(lines, line);
        let bad = |msg: String, findings: &mut Vec<Finding>| {
            findings.push(Finding {
                rule: BAD_SUPPRESSION.into(),
                file: rel.into(),
                line,
                msg,
                snippet: snippet.clone(),
            });
        };
        let rest = text[pos + "hetlint:".len()..].trim();
        let Some(inner_and_tail) = rest.strip_prefix("allow(") else {
            bad("expected `hetlint: allow(<rule>) -- <justification>`".into(), findings);
            continue;
        };
        let Some(close) = inner_and_tail.find(')') else {
            bad("unclosed `allow(`".into(), findings);
            continue;
        };
        let rules: Vec<String> = inner_and_tail[..close]
            .split(',')
            .map(|r| r.trim().to_string())
            .filter(|r| !r.is_empty())
            .collect();
        if rules.is_empty() {
            bad("`allow()` names no rule".into(), findings);
            continue;
        }
        let mut ok = true;
        for r in &rules {
            if !RULES.contains(&r.as_str()) {
                bad(format!("unknown rule `{r}` (known: {})", RULES.join(", ")), findings);
                ok = false;
            }
        }
        if !ok {
            continue;
        }
        let tail = inner_and_tail[close + 1..].trim();
        let Some(just) = tail.strip_prefix("--") else {
            bad("missing `-- <justification>` (the justification is mandatory)".into(), findings);
            continue;
        };
        let just = just.trim();
        if just.is_empty() {
            bad("empty justification (the justification is mandatory)".into(), findings);
            continue;
        }
        // A standalone comment line covers the next line that holds
        // code; a trailing comment covers its own line.
        let own_line_has_code = lexed.tokens.iter().any(|t| t.line == line);
        let target = if own_line_has_code {
            line
        } else {
            lexed
                .tokens
                .iter()
                .map(|t| t.line)
                .filter(|&l| l > line)
                .min()
                .unwrap_or(line)
        };
        sups.push(Suppression {
            line,
            target,
            rules,
            justification: just.to_string(),
            used: false,
        });
    }
    sups
}

fn snippet_at(lines: &[&str], line: usize) -> String {
    let s = lines.get(line.saturating_sub(1)).copied().unwrap_or("").trim();
    let mut s = s.to_string();
    if s.len() > 160 {
        s.truncate(160);
        s.push_str("...");
    }
    s
}

/// Lint one file's source; returns (unsuppressed findings, applied
/// suppressions).
fn lint_source(rel: &str, src: &str) -> (Vec<Finding>, Vec<Suppressed>) {
    let lexed = lex(src);
    let lines: Vec<&str> = src.lines().collect();
    let mask = test_mask(&lexed.tokens);
    let ts = &lexed.tokens;
    let hot = HOT_PATHS.contains(&rel);
    let mut raw: Vec<Finding> = Vec::new();
    let push = |raw: &mut Vec<Finding>, rule: &str, line: usize, msg: String| {
        raw.push(Finding {
            rule: rule.into(),
            file: rel.into(),
            line,
            msg,
            snippet: snippet_at(&lines, line),
        });
    };

    let mut index_count = 0usize;
    let mut index_excess_line: Option<usize> = None;
    let budget = INDEX_BUDGET
        .iter()
        .find(|(p, _)| *p == rel)
        .map(|&(_, b)| b)
        .unwrap_or(usize::MAX);

    for (i, t) in ts.iter().enumerate() {
        if mask[i] {
            continue;
        }
        match t.kind {
            Kind::Ident => match t.text.as_str() {
                "partial_cmp" => push(
                    &mut raw,
                    R1,
                    t.line,
                    "partial_cmp is not a total order on floats (NaN): use total_cmp".into(),
                ),
                "HashMap" | "HashSet" if in_det_modules(rel) => push(
                    &mut raw,
                    R3,
                    t.line,
                    format!(
                        "{} in a determinism-critical module: iteration order is \
                         per-process random; use BTreeMap/BTreeSet or sort first",
                        t.text
                    ),
                ),
                "SystemTime" if !wallclock_allowed(rel) => push(
                    &mut raw,
                    R4,
                    t.line,
                    "SystemTime outside the wall-clock allowlist (coordinator/, \
                     service_net/, substrate/bench.rs, main.rs, benches)"
                        .into(),
                ),
                "Instant"
                    if !wallclock_allowed(rel)
                        && ts.get(i + 1).is_some_and(|t| t.text == "::")
                        && ts.get(i + 2).is_some_and(|t| t.text == "now") =>
                {
                    push(
                        &mut raw,
                        R4,
                        t.line,
                        "Instant::now outside the wall-clock allowlist: core decisions \
                         must be functions of virtual time only"
                            .into(),
                    )
                }
                "unwrap" | "expect"
                    if hot && i > 0 && ts[i - 1].text == "." =>
                {
                    push(
                        &mut raw,
                        R5,
                        t.line,
                        format!(
                            "{} in an engine decision loop: a panic here abandons \
                             irrevocable decisions; justify the invariant or restructure",
                            t.text
                        ),
                    )
                }
                "unsafe" => push(
                    &mut raw,
                    R6,
                    t.line,
                    "unsafe is forbidden repo-wide".into(),
                ),
                "TIE_BAND" | "band_eq" | "band_ne" if hot => push(
                    &mut raw,
                    R7,
                    t.line,
                    format!(
                        "{} reintroduced in the tick core: the float tie band was \
                         removed by the Tick migration; event-time ties are exact \
                         integer tick compares",
                        t.text
                    ),
                ),
                _ => {}
            },
            Kind::Float if hot => {
                // epsilon-band literal: the characteristic constant of a
                // creeping float tie band (0 < x <= 1e-6).
                let lit = t.text.replace('_', "");
                let lit = lit.trim_end_matches("f64").trim_end_matches("f32");
                if let Ok(v) = lit.parse::<f64>() {
                    if v > 0.0 && v <= 1e-6 {
                        push(
                            &mut raw,
                            R7,
                            t.line,
                            format!(
                                "epsilon-band literal {} in the tick core: event-time \
                                 comparison is exact integer ticks, never banded",
                                t.text
                            ),
                        );
                    }
                }
            }
            Kind::Punct => match t.text.as_str() {
                "==" | "!=" | "<" | ">" | "<=" | ">=" if hot => {
                    let prev_float = i > 0 && ts[i - 1].kind == Kind::Float;
                    let next_float = ts.get(i + 1).is_some_and(|t| t.kind == Kind::Float)
                        || (ts.get(i + 1).is_some_and(|t| t.text == "-")
                            && ts.get(i + 2).is_some_and(|t| t.kind == Kind::Float));
                    if prev_float || next_float {
                        push(
                            &mut raw,
                            R7,
                            t.line,
                            format!(
                                "float-literal {} comparison in the tick core: event \
                                 time is integer engine::Tick; quantize once at entry \
                                 and compare ticks exactly",
                                t.text
                            ),
                        );
                    }
                }
                "==" | "!=" if in_core(rel) => {
                    let prev_float = i > 0 && ts[i - 1].kind == Kind::Float;
                    let next_float = ts.get(i + 1).is_some_and(|t| t.kind == Kind::Float)
                        || (ts.get(i + 1).is_some_and(|t| t.text == "-")
                            && ts.get(i + 2).is_some_and(|t| t.kind == Kind::Float));
                    if prev_float || next_float {
                        push(
                            &mut raw,
                            R2,
                            t.line,
                            format!(
                                "raw float {} in the decision core: compare quantized \
                                 engine::Tick values exactly, or justify an exact \
                                 structural comparison",
                                t.text
                            ),
                        );
                    }
                }
                "[" if hot
                    && i > 0
                    && (ts[i - 1].kind == Kind::Ident
                        || ts[i - 1].text == "]"
                        || ts[i - 1].text == ")") =>
                {
                    index_count += 1;
                    if index_count == budget.saturating_add(1) {
                        index_excess_line = Some(t.line);
                    }
                }
                _ => {}
            },
            _ => {}
        }
    }

    if hot && index_count > budget {
        let line = index_excess_line.unwrap_or(1);
        push(
            &mut raw,
            R5,
            line,
            format!(
                "indexing budget exceeded: {index_count} `expr[idx]` sites > budget \
                 {budget} (first excess here); remove index expressions or raise the \
                 budget in tools/hetlint/src/main.rs consciously"
            ),
        );
    }

    // apply suppressions
    let mut findings: Vec<Finding> = Vec::new();
    let mut sups = parse_suppressions(rel, &lexed, &lines, &mut findings);
    let mut suppressed: Vec<Suppressed> = Vec::new();
    for f in raw {
        let hit = sups
            .iter_mut()
            .find(|s| s.target == f.line && s.rules.iter().any(|r| r == &f.rule));
        match hit {
            Some(s) => {
                s.used = true;
                suppressed.push(Suppressed {
                    rule: f.rule,
                    file: f.file,
                    line: f.line,
                    justification: s.justification.clone(),
                });
            }
            None => findings.push(f),
        }
    }
    for s in &sups {
        if !s.used {
            findings.push(Finding {
                rule: UNUSED_SUPPRESSION.into(),
                file: rel.into(),
                line: s.line,
                msg: format!(
                    "suppression for {} matches no finding on line {}: remove it",
                    s.rules.join(", "),
                    s.target
                ),
                snippet: snippet_at(&lines, s.line),
            });
        }
    }
    findings.sort_by(|a, b| (a.line, &a.rule).cmp(&(b.line, &b.rule)));
    (findings, suppressed)
}

// ---------------------------------------------------------------------------
// Tree walk + report
// ---------------------------------------------------------------------------

/// The directories hetlint scans, relative to the repo root.
const SCAN_ROOTS: &[&str] = &["rust/src", "rust/tests", "rust/benches", "examples"];

struct Report {
    files_scanned: usize,
    findings: Vec<Finding>,
    suppressed: Vec<Suppressed>,
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else {
        return;
    };
    let mut paths: Vec<PathBuf> = entries.filter_map(|e| e.ok().map(|e| e.path())).collect();
    paths.sort();
    for p in paths {
        if p.is_dir() {
            collect_rs_files(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

fn run_lint(root: &Path) -> Report {
    let mut files: Vec<PathBuf> = Vec::new();
    for r in SCAN_ROOTS {
        collect_rs_files(&root.join(r), &mut files);
    }
    files.sort();
    let mut findings = Vec::new();
    let mut suppressed = Vec::new();
    let mut scanned = 0usize;
    for f in &files {
        let Ok(src) = fs::read_to_string(f) else {
            continue;
        };
        scanned += 1;
        let rel = f
            .strip_prefix(root)
            .unwrap_or(f)
            .to_string_lossy()
            .replace('\\', "/");
        let (fi, su) = lint_source(&rel, &src);
        findings.extend(fi);
        suppressed.extend(su);
    }
    findings.sort_by(|a, b| (&a.file, a.line, &a.rule).cmp(&(&b.file, b.line, &b.rule)));
    suppressed.sort_by(|a, b| (&a.file, a.line, &a.rule).cmp(&(&b.file, b.line, &b.rule)));
    Report { files_scanned: scanned, findings, suppressed }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn render_json(report: &Report) -> String {
    let mut out = String::new();
    out.push_str("{\n  \"tool\": \"hetlint\",\n  \"version\": 1,\n");
    out.push_str(&format!("  \"files_scanned\": {},\n", report.files_scanned));
    out.push_str("  \"findings\": [\n");
    for (i, f) in report.findings.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \"msg\": \"{}\", \"snippet\": \"{}\"}}{}\n",
            json_escape(&f.rule),
            json_escape(&f.file),
            f.line,
            json_escape(&f.msg),
            json_escape(&f.snippet),
            if i + 1 < report.findings.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n  \"suppressed\": [\n");
    for (i, s) in report.suppressed.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \"justification\": \"{}\"}}{}\n",
            json_escape(&s.rule),
            json_escape(&s.file),
            s.line,
            json_escape(&s.justification),
            if i + 1 < report.suppressed.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

fn repo_root() -> PathBuf {
    if let Some(arg) = std::env::args().nth(1) {
        return PathBuf::from(arg);
    }
    if let Ok(md) = std::env::var("CARGO_MANIFEST_DIR") {
        let p = Path::new(&md).join("../..");
        if p.join("Cargo.toml").exists() {
            return p;
        }
    }
    PathBuf::from(".")
}

fn main() -> ExitCode {
    let root = repo_root();
    let report = run_lint(&root);
    let json = render_json(&report);
    let json_path = root.join("ANALYSIS.json");
    if let Err(e) = fs::write(&json_path, &json) {
        eprintln!("hetlint: cannot write {}: {e}", json_path.display());
        return ExitCode::FAILURE;
    }
    for f in &report.findings {
        eprintln!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.msg);
        eprintln!("    {}", f.snippet);
    }
    if report.findings.is_empty() {
        println!(
            "hetlint OK: {} files scanned, 0 findings, {} justified suppressions ({})",
            report.files_scanned,
            report.suppressed.len(),
            json_path.display()
        );
        ExitCode::SUCCESS
    } else {
        eprintln!(
            "hetlint: {} finding(s) in {} files scanned ({} suppressed); fix them or \
             add `// hetlint: allow(<rule>) -- <justification>`",
            report.findings.len(),
            report.files_scanned,
            report.suppressed.len()
        );
        ExitCode::FAILURE
    }
}

// ---------------------------------------------------------------------------
// Tests: tokenizer, fixture corpus (one bad + one near-miss per rule),
// suppressions, and the real-tree-lints-clean integration check.
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(name: &str) -> String {
        let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(name);
        fs::read_to_string(&p).unwrap_or_else(|e| panic!("fixture {name}: {e}"))
    }

    fn rules_of(findings: &[Finding]) -> Vec<&str> {
        findings.iter().map(|f| f.rule.as_str()).collect()
    }

    // -- tokenizer ---------------------------------------------------------

    #[test]
    fn lexer_skips_strings_comments_chars() {
        let src = r###"
// partial_cmp in a comment
/* nested /* block partial_cmp */ still comment */
let s = "partial_cmp == 1.5 HashMap";
let r = r#"Instant::now() unsafe"#;
let c = '"';
let l: &'static str = s;
"###;
        let lexed = lex(src);
        assert!(!lexed.tokens.iter().any(|t| t.text == "partial_cmp"));
        assert!(!lexed.tokens.iter().any(|t| t.text == "HashMap"));
        assert!(!lexed.tokens.iter().any(|t| t.text == "Instant"));
        assert!(!lexed.tokens.iter().any(|t| t.text == "unsafe"));
        assert!(lexed.tokens.iter().any(|t| t.kind == Kind::Lifetime && t.text == "static"));
        assert_eq!(lexed.comments.len(), 1); // only the `//` line is collected
    }

    #[test]
    fn lexer_classifies_numbers() {
        let lexed = lex("let a = 1.5; let b = 15; let c = 1e-12; let d = 2.5f64; \
                         let e = 1f64; let f = 0x1E; let g = 1..n; let h = 1.max(2);");
        let nums: Vec<(&str, Kind)> = lexed
            .tokens
            .iter()
            .filter(|t| matches!(t.kind, Kind::Int | Kind::Float))
            .map(|t| (t.text.as_str(), t.kind))
            .collect();
        assert_eq!(
            nums,
            vec![
                ("1.5", Kind::Float),
                ("15", Kind::Int),
                ("1e-12", Kind::Float),
                ("2.5f64", Kind::Float),
                ("1f64", Kind::Float),
                ("0x1E", Kind::Int),
                ("1", Kind::Int),
                ("1", Kind::Int),
                ("2", Kind::Int),
            ]
        );
    }

    #[test]
    fn lexer_char_escapes_and_lines() {
        let lexed = lex("let q = '\\''; let b = '\\\\';\nlet x = 1;");
        assert!(lexed.tokens.iter().any(|t| t.text == "x" && t.line == 2));
    }

    #[test]
    fn test_mask_skips_cfg_test_items() {
        let src = "fn hot() { a.unwrap(); }\n#[cfg(test)]\nmod tests {\n    fn t() { b.unwrap(); }\n}\nfn tail() {}";
        let lexed = lex(src);
        let mask = test_mask(&lexed.tokens);
        let unmasked: Vec<&str> = lexed
            .tokens
            .iter()
            .zip(&mask)
            .filter(|(_, &m)| !m)
            .map(|(t, _)| t.text.as_str())
            .collect();
        assert!(unmasked.contains(&"hot"));
        assert!(unmasked.contains(&"tail"));
        assert!(!unmasked.contains(&"tests"));
        assert!(!unmasked.contains(&"b"));
    }

    // -- rule fixtures -----------------------------------------------------

    #[test]
    fn r1_fires_on_bad_and_not_on_near_miss() {
        let (bad, _) = lint_source("rust/src/substrate/stats.rs", &fixture("r1_bad.rs"));
        assert_eq!(rules_of(&bad), vec![R1], "{bad:?}");
        let (ok, _) = lint_source("rust/src/substrate/stats.rs", &fixture("r1_near_miss.rs"));
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn r2_fires_on_bad_and_not_on_near_miss() {
        let (bad, _) = lint_source("rust/src/lp/model.rs", &fixture("r2_bad.rs"));
        assert_eq!(rules_of(&bad), vec![R2, R2], "{bad:?}");
        let (ok, _) = lint_source("rust/src/lp/model.rs", &fixture("r2_near_miss.rs"));
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn r3_fires_on_bad_and_not_on_near_miss() {
        let (bad, _) = lint_source("rust/src/sim/mod.rs", &fixture("r3_bad.rs"));
        assert!(!bad.is_empty() && bad.iter().all(|f| f.rule == R3), "{bad:?}");
        let (ok, _) = lint_source("rust/src/sim/mod.rs", &fixture("r3_near_miss.rs"));
        assert!(ok.is_empty(), "{ok:?}");
        // same content outside the determinism-critical modules is fine
        let (ok2, _) = lint_source("rust/src/experiments/driver.rs", &fixture("r3_bad.rs"));
        assert!(ok2.is_empty(), "{ok2:?}");
    }

    #[test]
    fn r4_fires_on_bad_and_not_on_near_miss() {
        let (bad, _) = lint_source("rust/src/sched/service.rs", &fixture("r4_bad.rs"));
        assert_eq!(rules_of(&bad), vec![R4, R4], "{bad:?}");
        let (ok, _) = lint_source("rust/src/sched/service.rs", &fixture("r4_near_miss.rs"));
        assert!(ok.is_empty(), "{ok:?}");
        // the wall-clock allowlist really allows
        let (ok2, _) = lint_source("rust/src/coordinator/mod.rs", &fixture("r4_bad.rs"));
        assert!(ok2.is_empty(), "{ok2:?}");
        let (ok3, _) = lint_source("rust/benches/perf_hot_paths.rs", &fixture("r4_bad.rs"));
        assert!(ok3.is_empty(), "{ok3:?}");
        // the daemon edge (uptime accounting) is allowlisted too
        let (ok4, _) = lint_source("rust/src/service_net/server.rs", &fixture("r4_bad.rs"));
        assert!(ok4.is_empty(), "{ok4:?}");
    }

    #[test]
    fn r4_covers_the_obs_layer() {
        // rust/src/obs/ is deliberately NOT on the wall-clock allowlist:
        // trace events must carry virtual time only, or two runs of the
        // same schedule would write different traces.  Pin that a
        // wall-clock-stamping sink fires under every obs path and that
        // the real contract (vtime passed in, monotone seq) stays clean.
        for p in ["rust/src/obs/sink.rs", "rust/src/obs/event.rs", "rust/src/obs/metrics.rs"] {
            let (bad, _) = lint_source(p, &fixture("r4_obs_bad.rs"));
            assert_eq!(rules_of(&bad), vec![R4, R4], "{p}: {bad:?}");
            let (ok, _) = lint_source(p, &fixture("r4_obs_near_miss.rs"));
            assert!(ok.is_empty(), "{p}: {ok:?}");
        }
        // sanity: the same bad source IS allowed at the daemon edge,
        // where the metrics registry's wall-clock half legitimately lives
        let (ok2, _) = lint_source("rust/src/service_net/server.rs", &fixture("r4_obs_bad.rs"));
        assert!(ok2.is_empty(), "{ok2:?}");
    }

    #[test]
    fn r5_fires_on_bad_and_not_on_near_miss() {
        let (bad, _) = lint_source("rust/src/sched/est.rs", &fixture("r5_bad.rs"));
        assert_eq!(rules_of(&bad), vec![R5, R5], "{bad:?}");
        let (ok, _) = lint_source("rust/src/sched/est.rs", &fixture("r5_near_miss.rs"));
        assert!(ok.is_empty(), "{ok:?}");
        // unwrap outside the hot-path files is not this rule's business
        let (ok2, _) = lint_source("rust/src/experiments/driver.rs", &fixture("r5_bad.rs"));
        assert!(ok2.is_empty(), "{ok2:?}");
    }

    #[test]
    fn r5_indexing_budget_ratchets() {
        // est.rs budget is 15: 16 index expressions must fire, 15 must not
        let mut src = String::from("fn f(v: &[f64]) -> f64 {\n");
        for i in 0..16 {
            src.push_str(&format!("    let x{i} = v[{i}];\n"));
        }
        src.push_str("    0.0\n}\n");
        let (bad, _) = lint_source("rust/src/sched/est.rs", &src);
        assert_eq!(rules_of(&bad), vec![R5], "{bad:?}");
        assert!(bad[0].msg.contains("indexing budget"), "{bad:?}");
        let smaller = src.replace("    let x15 = v[15];\n", "");
        let (ok, _) = lint_source("rust/src/sched/est.rs", &smaller);
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn r6_fires_on_bad_and_not_on_near_miss() {
        let (bad, _) = lint_source("rust/src/lp/pdhg.rs", &fixture("r6_bad.rs"));
        assert_eq!(rules_of(&bad), vec![R6], "{bad:?}");
        let (ok, _) = lint_source("rust/src/lp/pdhg.rs", &fixture("r6_near_miss.rs"));
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn r7_fires_on_bad_and_not_on_near_miss() {
        let (bad, _) = lint_source("rust/src/sched/online.rs", &fixture("r7_bad.rs"));
        // TIE_BAND ident; `< 1e-9` (comparison + epsilon literal); `<= 0.5`; `> 1.5`
        assert_eq!(rules_of(&bad), vec![R7, R7, R7, R7, R7], "{bad:?}");
        let (ok, _) = lint_source("rust/src/sched/online.rs", &fixture("r7_near_miss.rs"));
        assert!(ok.is_empty(), "{ok:?}");
        // outside the hot-path files the tick-clock rule does not apply
        let (ok2, _) = lint_source("rust/src/sched/service.rs", &fixture("r7_bad.rs"));
        assert!(ok2.is_empty(), "{ok2:?}");
    }

    // -- suppressions ------------------------------------------------------

    #[test]
    fn suppression_with_justification_silences_and_is_recorded() {
        let (f, s) = lint_source("rust/src/sched/service.rs", &fixture("suppression_ok.rs"));
        assert!(f.is_empty(), "{f:?}");
        assert_eq!(s.len(), 2, "{s:?}");
        assert!(s.iter().all(|x| !x.justification.is_empty()));
    }

    #[test]
    fn bad_suppressions_are_findings() {
        let (f, s) = lint_source("rust/src/sched/service.rs", &fixture("suppression_bad.rs"));
        assert!(s.is_empty(), "{s:?}");
        let rules = rules_of(&f);
        // missing justification, unknown rule, and the two unsuppressed
        // wall-clock findings those directives failed to cover
        assert_eq!(
            rules,
            vec![BAD_SUPPRESSION, R4, BAD_SUPPRESSION, R4],
            "{f:?}"
        );
    }

    #[test]
    fn unused_suppression_is_a_finding() {
        let src = "// hetlint: allow(forbid-unsafe) -- nothing unsafe here\nfn f() {}\n";
        let (f, _) = lint_source("rust/src/lp/mod.rs", src);
        assert_eq!(rules_of(&f), vec![UNUSED_SUPPRESSION], "{f:?}");
    }

    #[test]
    fn standalone_suppression_covers_next_code_line() {
        let src = "fn f(t: std::time::Instant) {\n    // hetlint: allow(no-wallclock-in-core) -- metric only, never feeds placement\n    let t2 = std::time::Instant::now();\n    let _ = (t, t2);\n}\n";
        let (f, s) = lint_source("rust/src/sched/service.rs", src);
        assert!(f.is_empty(), "{f:?}");
        assert_eq!(s.len(), 1);
    }

    // -- the real tree -----------------------------------------------------

    #[test]
    fn real_tree_lints_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
        let report = run_lint(&root);
        assert!(
            report.files_scanned > 50,
            "scan found only {} files — wrong root?",
            report.files_scanned
        );
        let msgs: Vec<String> = report
            .findings
            .iter()
            .map(|f| format!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.msg))
            .collect();
        assert!(
            report.findings.is_empty(),
            "tree must lint clean; findings:\n{}",
            msgs.join("\n")
        );
        for s in &report.suppressed {
            assert!(
                !s.justification.is_empty(),
                "{}:{}: suppression without justification",
                s.file,
                s.line
            );
        }
    }

    #[test]
    fn json_is_well_formed_enough() {
        let report = Report {
            files_scanned: 1,
            findings: vec![Finding {
                rule: R1.into(),
                file: "a \"b\".rs".into(),
                line: 3,
                msg: "x\ny".into(),
                snippet: "\\".into(),
            }],
            suppressed: vec![],
        };
        let j = render_json(&report);
        assert!(j.contains("\"a \\\"b\\\".rs\""));
        assert!(j.contains("x\\ny"));
        assert!(j.contains("\"\\\\\""));
    }
}
