// fixture: HashMap/HashSet in a determinism-critical module must fire.
use std::collections::HashMap;
use std::collections::HashSet;

pub fn tally(keys: &[u32]) -> usize {
    let mut m: HashMap<u32, u32> = HashMap::new();
    let mut s: HashSet<u32> = HashSet::new();
    for &k in keys {
        *m.entry(k).or_insert(0) += 1;
        s.insert(k);
    }
    m.len() + s.len()
}
