// fixture: the obs layer is NOT on the wall-clock allowlist.  A sink
// that stamps events with real time would make traces differ run to
// run (and tempt someone to feed that timestamp back into a decision),
// so both reads here must fire.
pub struct WallClockSink {
    events: Vec<(f64, u64)>,
}

impl WallClockSink {
    pub fn emit(&mut self, payload: u64) {
        let t = std::time::Instant::now();
        let epoch = std::time::SystemTime::now();
        let _ = epoch;
        self.events.push((t.elapsed().as_secs_f64(), payload));
    }
}
