// fixture: trailing + standalone suppressions with justifications
// must silence both findings and be recorded as suppressed.
pub fn stamped() -> (f64, bool) {
    let a = std::time::Instant::now(); // hetlint: allow(no-wallclock-in-core) -- latency metric only, never feeds placement
    // hetlint: allow(no-wallclock-in-core) -- compares config stamps, not decisions
    let b = std::time::SystemTime::now().elapsed().is_ok();
    (a.elapsed().as_secs_f64(), b)
}
