// fixture: tie-band machinery creeping back into the tick core must
// fire: a banned identifier, an epsilon-band literal, and raw float
// comparisons of event time.
pub const TIE_BAND: f64 = 0.5;
pub fn leapfrog(finish: f64, best: f64) -> bool {
    let close = (finish - best).abs() < 1e-9;
    close || finish <= 0.5 || best > 1.5
}
