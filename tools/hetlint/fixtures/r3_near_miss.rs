// fixture: BTreeMap plus HashMap-in-comment/string must NOT fire.
use std::collections::BTreeMap;

// HashMap is banned here; BTreeMap iterates in key order.
pub fn tally(keys: &[u32]) -> usize {
    let msg = "no HashMap, no HashSet";
    let mut m: BTreeMap<u32, u32> = BTreeMap::new();
    for &k in keys {
        *m.entry(k).or_insert(0) += 1;
    }
    m.len() + msg.len()
}
