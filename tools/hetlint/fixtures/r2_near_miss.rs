// fixture: integer ==/!= and float-eq text in strings must NOT fire.
pub fn counts(n: usize, m: usize) -> bool {
    // x == 0.0 would be banned here
    let s = "x == 0.0";
    n == 0 && m != 1 && !s.is_empty()
}
