// fixture: float-total-order must fire exactly once (line 3).
pub fn sort_floats(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
