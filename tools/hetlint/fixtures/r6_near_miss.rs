// fixture: "unsafe" in comments/strings must NOT fire.
// unsafe code is forbidden repo-wide; this module has none.
pub fn peek(xs: &[f64]) -> f64 {
    let _doc = "unsafe is banned";
    xs.first().copied().unwrap_or(0.0)
}
