// fixture: an Instant passed in (no ::now) must NOT fire.
// Instant::now() is banned here; callers pass an Instant in.
pub fn elapsed_secs(t0: std::time::Instant) -> f64 {
    let _doc = "Instant::now and SystemTime live in coordinator/";
    t0.elapsed().as_secs_f64()
}
