// fixture: wall-clock reads outside the allowlist must fire twice.
pub fn stamp() -> (f64, bool) {
    let t = std::time::Instant::now();
    let epoch_ok = std::time::SystemTime::now().elapsed().is_ok();
    (t.elapsed().as_secs_f64(), epoch_ok)
}
