// fixture: unwrap/expect in an engine decision loop must fire twice.
pub fn pick(xs: &[f64]) -> f64 {
    let first = xs.first().unwrap();
    let last = xs.last().expect("non-empty");
    *first + *last
}
