// fixture: unsafe must fire exactly once.
pub fn peek(xs: &[f64]) -> f64 {
    unsafe { *xs.as_ptr() }
}
