// fixture: the real obs contract — events carry the *virtual* time the
// caller passes in, plus a monotone sequence number.  No wall-clock
// read anywhere, so nothing may fire, even though the code is all
// about "time".
pub struct VirtualTimeSink {
    events: Vec<(u64, f64)>,
    next_seq: u64,
}

impl VirtualTimeSink {
    pub fn emit(&mut self, vtime: f64) {
        let _doc = "Instant::now() and SystemTime stay at the daemon edge";
        let seq = self.next_seq;
        self.next_seq += 1;
        self.events.push((seq, vtime));
    }
}
