// fixture: exact tick compares, float-variable compares, band text in
// strings/comments and #[cfg(test)] content must NOT fire.
pub fn pick(finish: u64, best: u64, rank_a: f64, rank_b: f64) -> bool {
    // TIE_BAND and band_eq in a comment are fine
    let doc = "band_eq(TIE_BAND) <= 1e-9";
    let tick_ok = finish <= best; // integer tick compare
    let rank_ok = rank_a < rank_b; // float *variable* compare: ranks, not times
    tick_ok && rank_ok && !doc.is_empty()
}

#[cfg(test)]
mod tests {
    const TIE_BAND: f64 = 1e-9;

    #[test]
    fn t() {
        assert!(super::pick(1, 2, 0.5, 1.5) || TIE_BAND < 1e-6);
    }
}
