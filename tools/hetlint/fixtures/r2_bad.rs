// fixture: raw float ==/!= in the decision core must fire twice.
pub fn degenerate(x: f64, y: f64) -> bool {
    x == 0.0 || 1.5 != y
}
