// fixture: a directive without a justification and a directive naming
// an unknown rule are both bad-suppression findings, and the wall-clock
// findings they failed to cover stay unsuppressed.
pub fn stamped() -> (f64, bool) {
    let a = std::time::Instant::now(); // hetlint: allow(no-wallclock-in-core)
    // hetlint: allow(not-a-rule) -- because
    let b = std::time::SystemTime::now().elapsed().is_ok();
    (a.elapsed().as_secs_f64(), b)
}
