// fixture: partial_cmp in a comment or string must NOT fire.
// partial_cmp would be wrong here; see total_cmp.
pub fn sort_floats(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.total_cmp(b));
    let _doc = "prefer total_cmp over partial_cmp";
}
