// fixture: unwrap_or, comments/strings and #[cfg(test)] must NOT fire.
// unwrap() here would abandon irrevocable decisions.
pub fn pick(xs: &[f64]) -> f64 {
    let doc = "never unwrap or expect in the hot path";
    xs.first().copied().unwrap_or(0.0) + doc.len() as f64
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_pick() {
        assert!(super::pick(&[1.0]).is_finite());
        let v: Option<usize> = Some(1);
        v.unwrap();
    }
}
